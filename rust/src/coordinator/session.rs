//! SdSession: one request's full edge–channel–cloud speculative-decoding
//! loop, with the latency ledger the paper's figures are built from.
//!
//! Latency model (matching [22]'s decomposition, §4 of the paper):
//!   total = handshake (Hello up + HelloAck down) + sum over batches of
//!     t_slm (measured draft compute) + t_uplink (simulated: frame bits /
//!     bandwidth + propagation) + t_llm (measured verify compute) +
//!     t_downlink (simulated feedback).
//! Compute can optionally be *modeled* (fixed per-call costs) for
//! hardware-independent, exactly reproducible sweeps — used by the
//! synthetic-backend benches; PJRT benches default to measured.
//!
//! Since protocol v2 the session speaks typed frames through a
//! [`LinkTransport`]: drafts and feedback are encoded exactly once, by
//! the transport, and the cloud side decodes the same bytes — there is
//! no codec call in the session itself.  The one-time handshake bits are
//! ledgered in `uplink_bits`/`downlink_bits` (broken out in
//! `SessionResult` so bit-accounting tests stay exact).
//!
//! Since protocol v3 the loop is a *pipelined state machine* rather than
//! a lock-step request/reply exchange: the edge keeps up to
//! `pipeline_depth` sequenced drafts in flight, speculatively continuing
//! from its own draft tokens (the cloud forgoes the bonus token on full
//! acceptance so both contexts stay aligned), and a rejection rolls the
//! speculated KV/context back and bumps the speculation epoch so the
//! cloud discards every stale in-flight draft.  The engine runs on an
//! in-flight ledger in virtual time — uplink, verify, and downlink
//! stages each serialize on their own resource, so drafting overlaps
//! verification and the high-RTT round trip is hidden.  `pipeline_depth
//! = 1` reproduces the v2 alternating protocol bit for bit (pinned by
//! `tests/pipelining.rs` against [`SdSession::run_reference_lockstep`]),
//! and every pipelined run stays a pure function of (config, seed).
//!
//! Since protocol v4 a pipelined session may additionally speculate
//! *token trees* (`tree_branching >= 2`): each frame carries rejection
//! continuations around the linear trunk, the cloud's tree walk can
//! survive a rejection into a sibling chain, and the edge branches its
//! rollback to the surviving node instead of the epoch root.
//! `tree_branching = 1` is the v3 linear pipeline bit for bit (pinned
//! by `tests/tree_speculation.rs`).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::channel::SimulatedLink;
use crate::cloud::{CloudNode, Verdict};
use crate::control::{AdaptiveMode, BatchOutcome, ControlLoop, KnobPoint, Knobs};
use crate::edge::EdgeNode;
use crate::model::{DraftLm, TargetLm};
use crate::protocol::{
    negotiate, Direction, Ext, FeedbackV2, Frame, FrameView, LinkTransport, SeqAck, SeqDraft,
    Transport, TreeAck, TreeDraft, WireArena, PROTOCOL_V3, PROTOCOL_V4,
};
use crate::sqs::Policy;
use crate::trace::{Dir, TraceData, TraceSink, ACTOR_CLOUD, ACTOR_LINK};
use crate::util::stats::Summary;

/// How compute time enters the latency ledger.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TimingMode {
    /// wall-clock of the actual PJRT/synthetic calls
    Measured,
    /// fixed seconds per SLM draft step and per LLM verify call
    Modeled { slm_step_s: f64, llm_call_s: f64 },
}

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub policy: Policy,
    pub temp: f32,
    pub ell: u32,
    /// per-batch uplink budget B, in bits (paper: 5000)
    pub budget_bits: usize,
    pub max_new_tokens: usize,
    pub max_batch_drafts: usize,
    pub seed: u64,
    pub timing: TimingMode,
    /// link-adaptive control plane (Off = today's fixed knobs, bit-exact)
    pub adaptive: AdaptiveMode,
    /// maximum unacknowledged drafts in flight (1 = the v2 alternating
    /// protocol, bit-exact; >= 2 negotiates protocol v3 and pipelines)
    pub pipeline_depth: usize,
    /// token-tree branching factor (1 = the v3 linear pipeline,
    /// bit-exact; >= 2 with `pipeline_depth >= 2` negotiates protocol
    /// v4 and ships `DraftTree` frames whose rejection continuations
    /// the cloud can survive into)
    pub tree_branching: usize,
    /// bounded per-frame retransmit budget under a lossy channel; once a
    /// frame has been lost `max_retransmits + 1` times the session falls
    /// back to an epoch resync (uplink) or errors out (handshake,
    /// downlink).  Irrelevant at loss = 0: a lossless link never enters
    /// the recovery path at all.
    pub max_retransmits: u32,
    /// virtual seconds the edge waits past a frame's expected delivery
    /// before declaring it lost and re-sending
    pub loss_timeout_s: f64,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.8,
            ell: 100,
            budget_bits: 5000,
            max_new_tokens: 64,
            max_batch_drafts: 15,
            seed: 0,
            timing: TimingMode::Measured,
            adaptive: AdaptiveMode::Off,
            pipeline_depth: 1,
            tree_branching: 1,
            max_retransmits: 4,
            loss_timeout_s: 0.05,
        }
    }
}

/// Consecutive epoch-resyncs (uplink retransmit budgets exhausted
/// back-to-back) before the session gives up with a clean error instead
/// of spinning forever against a channel that drops everything.
const MAX_RESYNC_STREAK: u32 = 16;

/// Per-batch record (diagnostics, figure generation, knob traces).
#[derive(Clone, Debug)]
pub struct BatchRecord {
    pub drafted: usize,
    pub accepted: usize,
    pub rejected: bool,
    pub dist_bits: usize,
    pub frame_bits: usize,
    /// downlink feedback frame size, bits (v2: varies with extensions)
    pub feedback_bits: usize,
    pub mean_k: f64,
    /// mean dropped mass alpha_n over the round's drafted nodes
    pub mean_alpha: f64,
    /// wire nodes the round's frame carried (== `drafted` on linear
    /// frames; larger for protocol-v4 trees, whose `drafted` stays the
    /// per-path trunk length)
    pub tree_nodes: usize,
    /// the control-plane knobs (K^t, ℓ^t, B^t) in force this round
    pub knobs: KnobPoint,
    pub t_slm: f64,
    pub t_uplink: f64,
    pub t_llm: f64,
    pub t_downlink: f64,
}

/// Aggregated result of a session.
#[derive(Clone, Debug)]
pub struct SessionResult {
    pub prompt_len: usize,
    pub tokens: Vec<u16>,
    pub batches: Vec<BatchRecord>,
    pub n_rej: usize,
    /// in-flight depth the session ran at (1 = alternating)
    pub pipeline_depth: usize,
    /// token-tree branching ceiling the session ran at (1 = linear)
    pub tree_branching: usize,
    /// speculative batches the cloud discarded as stale (pipelined
    /// sessions; their wire bits still count in the ledgers, but they
    /// produce no `BatchRecord`)
    pub discarded_batches: usize,
    /// frames re-sent after a channel loss (handshake, draft uplink, and
    /// duplicate-draft feedback recovery; 0 at loss = 0)
    pub retransmits: u64,
    /// epoch resyncs forced by an exhausted uplink retransmit budget:
    /// the edge rolled back to the last acknowledged context and
    /// redrafted (0 at loss = 0)
    pub loss_resyncs: u64,
    /// virtual seconds spent in loss recovery (loss timeouts plus
    /// retransmission airtime).  Kept out of the per-stage
    /// `t_uplink_s`/`t_downlink_s` ledgers so the control plane's link
    /// estimator never mistakes loss for congestion.
    pub t_recovery_s: f64,
    /// End-to-end virtual time.  At depth 1 this is the exact sum of the
    /// four stage components (the alternating protocol serializes them);
    /// at depth >= 2 it is the pipeline makespan, which overlap makes
    /// *smaller* than the component sum.
    pub total_time_s: f64,
    pub t_slm_s: f64,
    pub t_uplink_s: f64,
    pub t_llm_s: f64,
    pub t_downlink_s: f64,
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    /// one-time Hello bits (included in `uplink_bits`)
    pub handshake_uplink_bits: u64,
    /// one-time HelloAck bits (included in `downlink_bits`)
    pub handshake_downlink_bits: u64,
    pub conformal_empirical_alpha: Option<f64>,
    pub conformal_bound: Option<f64>,
    pub conformal_t: Option<u64>,
    /// rejections attributed (by dominant share) to SLM-LLM mismatch
    /// (engine path only; lockstep reports 0)
    pub reject_mismatch: u64,
    /// rejections attributed to sparsification/quantization distortion
    pub reject_distortion: u64,
    /// summed mismatch share over attributed rejections (the paper's
    /// decomposition: mismatch mass + distortion mass == #attributed)
    pub reject_mass_mismatch: f64,
    /// summed distortion share over attributed rejections
    pub reject_mass_distortion: f64,
    /// unweighted mean of the per-round `mean_alpha` diagnostics
    pub mean_alpha: f64,
}

impl SessionResult {
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The paper's resampling-rate metric: N_rej / #batches.
    pub fn resampling_rate(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.n_rej as f64 / self.batches.len() as f64
        }
    }

    /// Fraction of drafted tokens accepted.
    pub fn acceptance_rate(&self) -> f64 {
        let drafted: usize = self.batches.iter().map(|b| b.drafted).sum();
        let accepted: usize = self.batches.iter().map(|b| b.accepted).sum();
        if drafted == 0 { 0.0 } else { accepted as f64 / drafted as f64 }
    }

    pub fn mean_k(&self) -> f64 {
        let mut s = Summary::new();
        for b in &self.batches {
            s.add(b.mean_k);
        }
        s.mean()
    }

    pub fn bits_per_token(&self) -> f64 {
        let n = self.new_tokens();
        if n == 0 { 0.0 } else { self.uplink_bits as f64 / n as f64 }
    }

    /// Mean wire bits per speculative round — the control plane's AIMD
    /// budget basis (0 for the batchless AR baseline).
    pub fn mean_bits_per_round(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.uplink_bits as f64 / self.batches.len() as f64
        }
    }

    pub fn latency_per_token(&self) -> f64 {
        let n = self.new_tokens();
        if n == 0 { 0.0 } else { self.total_time_s / n as f64 }
    }
}

/// One request, one edge, one cloud, one link.
pub struct SdSession<D: DraftLm, T: TargetLm> {
    pub edge: EdgeNode<D>,
    pub cloud: CloudNode<T>,
    /// typed frame channel over the simulated link
    pub transport: LinkTransport,
    pub cfg: SessionConfig,
    /// link-adaptive control plane, consulted once per batch
    pub control: ControlLoop,
    /// flight recorder (disabled by default: no event is constructed);
    /// only [`Self::run`]'s engine emits — the frozen reference lockstep
    /// stays untouched
    pub tracer: TraceSink,
    /// canonical committed sequence (prompt + verified tokens)
    seq: Vec<u16>,
}

impl<D: DraftLm, T: TargetLm> SdSession<D, T> {
    pub fn new(draft: D, target: T, link: SimulatedLink, cfg: SessionConfig) -> Self {
        let vocab = draft.vocab();
        let mut edge = EdgeNode::new(
            draft,
            cfg.policy,
            cfg.ell,
            cfg.budget_bits,
            cfg.max_batch_drafts,
            cfg.seed ^ 0xE,
        );
        // runtime-varying K needs the per-token-K wire scheme
        if matches!(cfg.adaptive, AdaptiveMode::Aimd { .. }) {
            edge.use_adaptive_scheme();
        }
        // a depth >= 2 session wants sequenced drafts: advertise v3 in
        // the handshake (a v2 peer negotiates the session back down and
        // the engine falls back to strict alternation); with a tree
        // branching factor on top it advertises v4 (a v3 peer lands the
        // session back on the linear pipeline)
        if cfg.pipeline_depth > 1 {
            edge.wire.set_version(if cfg.tree_branching > 1 {
                PROTOCOL_V4
            } else {
                PROTOCOL_V3
            });
        }
        let control = ControlLoop::for_session(
            cfg.adaptive,
            cfg.policy,
            cfg.max_batch_drafts,
            cfg.budget_bits,
            vocab,
            cfg.pipeline_depth,
            cfg.tree_branching,
        );
        let cloud = CloudNode::new(target, cfg.seed ^ 0xC);
        SdSession {
            edge,
            cloud,
            transport: LinkTransport::new(link),
            cfg,
            control,
            tracer: TraceSink::null(),
            seq: Vec::new(),
        }
    }

    /// Install a flight-recorder sink (events stamped in the engine's
    /// virtual clock; the edge is actor 0).
    pub fn set_tracer(&mut self, sink: TraceSink) {
        self.tracer = sink;
    }

    /// Run the speculative-decoding loop to completion.
    ///
    /// Every depth runs through the protocol-v3 in-flight ledger engine;
    /// at `pipeline_depth = 1` the engine degenerates to the v2
    /// alternating protocol and is bit-identical to
    /// [`Self::run_reference_lockstep`] (pinned by `tests/pipelining.rs`).
    pub fn run(&mut self, prompt: &[u16]) -> Result<SessionResult> {
        let hs = self.start_and_handshake(prompt)?;
        self.run_engine(prompt, hs)
    }

    /// Start both contexts and run the Hello/HelloAck exchange over the
    /// link, returning the one-time handshake ledger entries.
    fn start_and_handshake(&mut self, prompt: &[u16]) -> Result<HandshakeLedger> {
        self.edge.start(prompt)?;
        self.cloud.start(prompt)?;
        self.seq = prompt.to_vec();

        // ---- handshake: negotiate version + codec parameters ------------
        // The edge advertises its codec config; the cloud validates and
        // acks.  Both frames ride the simulated link, so their bits and
        // latency are in the ledger like every other wire event.
        let hello = self.edge.wire.hello().map_err(|e| anyhow::anyhow!("handshake: {e}"))?;
        // A lost handshake frame is recovered by bounded blind re-send:
        // neither side has negotiated loss-recovery semantics yet, so the
        // timeout-and-retry here is the whole protocol.  At loss = 0 the
        // loops never run and the ledger is bit-identical to before.
        let hello_frame = Frame::Hello(hello);
        let mut retransmits = 0u64;
        let mut t_recovery = 0.0f64;
        let mut d_hello = self.transport.send_frame(
            Direction::Up,
            &hello_frame,
            &mut self.edge.wire,
            0.0,
        )?;
        let mut up_bits = d_hello.bits as u64;
        while self.transport.last_send_lost() {
            retransmits += 1;
            if retransmits > self.cfg.max_retransmits as u64 {
                bail!(
                    "handshake: Hello lost beyond recovery ({} retries)",
                    self.cfg.max_retransmits
                );
            }
            t_recovery += d_hello.latency_s() + self.cfg.loss_timeout_s;
            d_hello = self.transport.send_frame(
                Direction::Up,
                &hello_frame,
                &mut self.edge.wire,
                0.0,
            )?;
            up_bits += d_hello.bits as u64;
        }
        let heard = match self.transport.recv_frame(Direction::Up, &mut self.edge.wire)? {
            Frame::Hello(h) => h,
            other => bail!("handshake: expected Hello on the uplink, got {}", other.name()),
        };
        let ack = negotiate(&heard).map_err(|e| anyhow::anyhow!("handshake rejected: {e}"))?;
        let ack_frame = Frame::HelloAck(ack);
        let mut d_ack = self.transport.send_frame(
            Direction::Down,
            &ack_frame,
            &mut self.edge.wire,
            0.0,
        )?;
        let mut down_bits = d_ack.bits as u64;
        let mut ack_losses = 0u64;
        while self.transport.last_send_lost() {
            ack_losses += 1;
            if ack_losses > self.cfg.max_retransmits as u64 {
                bail!(
                    "handshake: HelloAck lost beyond recovery ({} retries)",
                    self.cfg.max_retransmits
                );
            }
            retransmits += 1;
            // the edge times out and re-sends the Hello; the cloud treats
            // the duplicate as a re-ask and answers again.  The duplicate
            // Hello itself rides the lossy uplink, but its loss only adds
            // another timeout round, which the bounded loop already models.
            t_recovery += d_ack.latency_s() + self.cfg.loss_timeout_s;
            let d_dup = self.transport.send_frame(
                Direction::Up,
                &hello_frame,
                &mut self.edge.wire,
                0.0,
            )?;
            up_bits += d_dup.bits as u64;
            if self.transport.last_send_lost() {
                t_recovery += d_dup.latency_s() + self.cfg.loss_timeout_s;
                continue;
            }
            let _ = self.transport.recv_frame(Direction::Up, &mut self.edge.wire)?;
            d_ack = self.transport.send_frame(
                Direction::Down,
                &ack_frame,
                &mut self.edge.wire,
                0.0,
            )?;
            down_bits += d_ack.bits as u64;
        }
        let ack = match self.transport.recv_frame(Direction::Down, &mut self.edge.wire)? {
            Frame::HelloAck(a) => a,
            other => bail!("handshake: expected HelloAck, got {}", other.name()),
        };
        if !ack.ok {
            bail!("handshake: cloud rejected the session");
        }
        if !self.edge.wire.matches(&ack) {
            bail!("handshake: ack does not match the advertised codec config");
        }
        Ok(HandshakeLedger {
            up_bits,
            down_bits,
            t_up: d_hello.latency_s(),
            t_down: d_ack.latency_s(),
            retransmits,
            t_recovery,
        })
    }

    /// The pipelined in-flight ledger engine (protocol v3).
    ///
    /// The edge drafts while up to `pipeline_depth` sequenced drafts are
    /// unacknowledged, speculatively continuing from its own draft
    /// tokens; the cloud forgoes the bonus token on full acceptance so
    /// the contexts stay aligned, and a rejection bumps the speculation
    /// epoch so stale in-flight drafts are discarded on both ends.
    ///
    /// Virtual time: the cloud half of each round is evaluated eagerly
    /// when the frame is sent — legal because frames are served in FIFO
    /// order and no information reaches the edge before the feedback's
    /// computed arrival time — while the uplink transmitter, verify
    /// server, and downlink transmitter each serialize on their own
    /// `busy-until` clock, which is what lets draft compute overlap the
    /// round trip.
    fn run_engine(&mut self, prompt: &[u16], hs: HandshakeLedger) -> Result<SessionResult> {
        let depth_cfg = self.cfg.pipeline_depth.max(1);
        let pipelined = depth_cfg > 1 && self.edge.wire.pipelining();
        // token trees need a pipelined v4 session; the per-round knob can
        // still collapse an eligible session to linear DraftSeq frames
        let branching_cfg = self.cfg.tree_branching.max(1);
        let tree_capable = pipelined && branching_cfg > 1 && self.edge.wire.trees();

        let mut uplink_bits = hs.up_bits;
        let mut downlink_bits = hs.down_bits;
        let (mut t_slm, mut t_llm) = (0.0, 0.0);
        let mut t_up = hs.t_up;
        let mut t_down = hs.t_down;
        let mut batches: Vec<BatchRecord> = Vec::new();
        let mut n_rej = 0usize;
        let mut discarded = 0usize;
        // rejection-attribution rollups (paper's mismatch/distortion
        // decomposition; observational — no extra RNG draws anywhere)
        let mut reject_mismatch = 0u64;
        let mut reject_distortion = 0u64;
        let mut reject_mass_mismatch = 0.0f64;
        let mut reject_mass_distortion = 0.0f64;
        // loss-recovery ledger (all zero at loss = 0: the recovery paths
        // below are gated on `Transport::last_send_lost`, which a
        // lossless link never raises)
        let mut retransmits = hs.retransmits;
        let mut loss_resyncs = 0u64;
        let mut t_recovery = hs.t_recovery;
        let mut consecutive_resyncs = 0u32;

        // virtual timeline (handshake is sequential: up then down, plus
        // any timeout-and-retry rounds the lossy link forced on it)
        let hs_done = hs.t_up + hs.t_down + hs.t_recovery;
        let mut t_edge = hs_done; // when the edge is next free
        let mut up_busy = hs_done; // uplink transmitter busy-until
        let mut cloud_free = hs_done; // verify server busy-until
        let mut down_busy = hs_done; // downlink transmitter busy-until
        let mut last_arrival = hs_done; // FIFO downlink: arrivals monotone

        let mut in_flight: VecDeque<InFlightBatch> = VecDeque::new();
        // per-session decode scratch: uplink frames parse into this arena
        // as borrowed views, so steady-state verify allocates no frame
        let mut arena = WireArena::new();
        let mut speculated = 0usize; // uncommitted speculative tokens in flight
        let mut next_seq: u16 = 0;
        let mut edge_epoch: u8 = 0;
        let mut cloud_epoch: u8 = 0;
        let mut cloud_prev = *prompt.last().unwrap();
        let mut window = depth_cfg; // live depth knob D^t
        let mut exhausted = false; // draft context ran out mid-request
        let mut last_knobs: Option<Knobs> = None; // KnobChange on change only

        loop {
            let produced = self.seq.len() - prompt.len();
            let window_eff = if pipelined { window.clamp(1, depth_cfg) } else { 1 };
            let can_draft = !exhausted
                && in_flight.len() < window_eff
                && produced + speculated < self.cfg.max_new_tokens
                && self.room_left_at(self.seq.len() + speculated);

            if can_draft {
                // ---- draft the next batch (possibly speculative) --------
                let ctx_before = self.edge.context_len();
                let knobs = self.control.begin_batch();
                if last_knobs != Some(knobs) {
                    last_knobs = Some(knobs);
                    self.tracer.emit(t_edge, 0, || TraceData::KnobChange {
                        k: match knobs.sparsifier {
                            Some(crate::sqs::Sparsifier::TopK(k)) => k as i64,
                            _ => -1,
                        },
                        ell: knobs.ell,
                        budget_bits: knobs.budget_bits,
                        depth: knobs.pipeline_depth,
                        branching: knobs.tree_branching,
                    });
                }
                window = knobs.pipeline_depth.max(1);
                let branching = if tree_capable {
                    knobs.tree_branching.clamp(1, branching_cfg)
                } else {
                    1
                };
                let remaining = self.cfg.max_new_tokens - (produced + speculated);
                // a v4 session whose branching knob collapsed to 1 drafts
                // (and ships) exactly the linear v3 shape for that round
                let (body, parents, trunk, node_dist_bits, node_ks, node_alphas, node_tvs,
                     leaf_count, t_slm_raw) =
                    if branching >= 2 {
                        let dt = self.edge.draft_tree_knobs(self.cfg.temp, remaining, &knobs)?;
                        let trunk = dt.trunk_tokens();
                        let leaves = dt.leaf_count();
                        (
                            dt.frame,
                            Some(dt.parents),
                            Some(trunk),
                            dt.dist_bits,
                            dt.ks,
                            dt.alphas,
                            dt.tvs,
                            leaves,
                            dt.t_slm,
                        )
                    } else {
                        let db = self.edge.draft_batch_knobs(self.cfg.temp, remaining, &knobs)?;
                        (db.frame, None, None, db.dist_bits, db.ks, db.alphas, db.tvs, 1,
                         db.t_slm)
                    };
                let tree_nodes = body.tokens.len();
                let l = trunk.as_ref().map_or(tree_nodes, Vec::len);
                if l == 0 {
                    exhausted = true; // context full: drain what is in flight
                    continue;
                }
                // compute scales with the whole node table, not the trunk
                let slm_time = match self.cfg.timing {
                    TimingMode::Measured => t_slm_raw,
                    TimingMode::Modeled { slm_step_s, .. } => slm_step_s * tree_nodes as f64,
                };
                let draft_done = t_edge + slm_time;
                t_edge = draft_done;

                let seq = next_seq;
                next_seq = next_seq.wrapping_add(1);
                let dist_bits: usize = node_dist_bits.iter().sum();
                let mean_k = node_ks.iter().sum::<usize>() as f64 / tree_nodes as f64;
                let mean_alpha =
                    node_alphas.iter().map(|&a| a as f64).sum::<f64>() / tree_nodes as f64;

                // ---- uplink: encode once, serialize on the channel ------
                let up_frame = match parents {
                    Some(parents) => {
                        Frame::DraftTree(TreeDraft { seq, epoch: edge_epoch, parents, frame: body })
                    }
                    None if pipelined => {
                        Frame::DraftSeq(SeqDraft { seq, epoch: edge_epoch, frame: body })
                    }
                    None => Frame::Draft(body),
                };
                let mut d_up = self.transport.send_frame(
                    Direction::Up,
                    &up_frame,
                    &mut self.edge.wire,
                    0.0,
                )?;
                uplink_bits += d_up.bits as u64;
                let air_s = d_up.bits as f64 / self.transport.link.cfg.uplink_bps;
                let mut send_start = draft_done.max(up_busy);
                up_busy = send_start + air_s;
                let queue_wait_s = send_start - draft_done;
                // ---- uplink loss recovery (never entered at loss = 0, so
                // the lossless ledger is bit-identical by construction).
                // A lost draft is invisible to the cloud: the edge learns
                // of it only by feedback timeout, then re-sends the same
                // encoded frame.  Once the retransmit budget is spent it
                // stops betting on the channel — epoch-resync back to the
                // pre-batch context and redraft from there, reusing the
                // sequence number the cloud never saw.
                let mut up_attempt = 0u32;
                let mut resynced = false;
                while self.transport.last_send_lost() {
                    up_attempt += 1;
                    // the loss is observed one airtime + timeout after the
                    // transmitter started; the wasted spend is recovery
                    // time, not uplink time, so the control plane's link
                    // estimator never reads loss as congestion
                    t_recovery += air_s + self.cfg.loss_timeout_s;
                    let retry_at = send_start + air_s + self.cfg.loss_timeout_s;
                    if up_attempt > self.cfg.max_retransmits {
                        self.edge.resync_to(ctx_before)?;
                        next_seq = seq;
                        loss_resyncs += 1;
                        consecutive_resyncs += 1;
                        let epoch = edge_epoch;
                        self.tracer.emit(retry_at, 0, || TraceData::LossResync {
                            batch_seq: seq,
                            epoch,
                        });
                        if consecutive_resyncs > MAX_RESYNC_STREAK {
                            bail!(
                                "uplink lost beyond recovery: {consecutive_resyncs} \
                                 consecutive epoch resyncs (loss model defeats the \
                                 retry budget of {})",
                                self.cfg.max_retransmits
                            );
                        }
                        t_edge = t_edge.max(retry_at);
                        resynced = true;
                        break;
                    }
                    retransmits += 1;
                    let attempt = up_attempt;
                    self.tracer.emit(retry_at, 0, || TraceData::Retransmit {
                        dir: Dir::Up,
                        batch_seq: seq,
                        attempt,
                    });
                    d_up = self.transport.send_frame(
                        Direction::Up,
                        &up_frame,
                        &mut self.edge.wire,
                        0.0,
                    )?;
                    uplink_bits += d_up.bits as u64;
                    send_start = retry_at.max(up_busy);
                    up_busy = send_start + air_s;
                }
                if resynced {
                    continue;
                }
                consecutive_resyncs = 0;
                let up_time = d_up.latency_s();
                let delivered_at = send_start + up_time;
                let up_kind: &'static str = match &up_frame {
                    Frame::DraftTree(_) => "draft_tree",
                    Frame::DraftSeq(_) => "draft_seq",
                    _ => "draft",
                };
                self.tracer.emit(draft_done, 0, || TraceData::DraftSent {
                    batch_seq: seq,
                    epoch: edge_epoch,
                    drafted: l,
                    nodes: tree_nodes,
                    slm_s: slm_time,
                });
                if queue_wait_s > 0.0 {
                    self.tracer.emit(draft_done, ACTOR_LINK, || TraceData::QueueWait {
                        wait_s: queue_wait_s,
                        bits: d_up.bits,
                    });
                }
                self.tracer.emit(send_start, 0, || TraceData::FrameTx {
                    dir: Dir::Up,
                    frame: up_kind,
                    bits: d_up.bits,
                    air_s,
                });
                self.tracer.emit(delivered_at, ACTOR_CLOUD, || TraceData::FrameRx {
                    dir: Dir::Up,
                    frame: up_kind,
                    bits: d_up.bits,
                });

                // ---- cloud: decode the wire bytes + verify.  Evaluated
                // eagerly at send time (FIFO service order == send order;
                // nothing reaches the edge before `arrive_at`).  The
                // frame parses as a borrowed view into the session arena
                // — the cloud verifies straight off the borrowed token
                // slices, so no owned frame is ever materialized --------
                let (verdict, llm_time, fb_out, full_trunk) = match self
                    .transport
                    .recv_frame_view(Direction::Up, &mut self.edge.wire, &mut arena)?
                {
                    FrameView::Draft(f) if !pipelined => {
                        let prev = *self.seq.last().unwrap();
                        let v = self.cloud.verify_with_prev_tokens(
                            f.batch_id,
                            f.tokens,
                            prev,
                            self.cfg.temp,
                        )?;
                        let llm = match self.cfg.timing {
                            TimingMode::Measured => v.t_llm,
                            TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
                        };
                        let fb = v.feedback_v2(Vec::new());
                        (Some(v), llm, fb, false)
                    }
                    FrameView::DraftSeq { seq: sd_seq, epoch: sd_epoch, frame } if pipelined => {
                        if sd_epoch != cloud_epoch {
                            // stale: drafted on a branch a rejection killed
                            (
                                None,
                                0.0,
                                FeedbackV2::discard(frame.batch_id, sd_seq, sd_epoch),
                                false,
                            )
                        } else {
                            let v = self.cloud.verify_pipelined_tokens(
                                frame.batch_id,
                                frame.tokens,
                                cloud_prev,
                                self.cfg.temp,
                            )?;
                            if v.rejected {
                                cloud_epoch = cloud_epoch.wrapping_add(1);
                            }
                            cloud_prev = *v.committed.last().unwrap();
                            let llm = match self.cfg.timing {
                                TimingMode::Measured => v.t_llm,
                                TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
                            };
                            let mut fb = v.feedback_v2(Vec::new());
                            fb.exts.push(Ext::Ack(SeqAck {
                                seq: sd_seq,
                                epoch: sd_epoch,
                                discard: false,
                            }));
                            (Some(v), llm, fb, false)
                        }
                    }
                    FrameView::DraftTree(td) if tree_capable => {
                        if td.epoch != cloud_epoch {
                            // stale tree: same linear discard ack, so the
                            // edge's ledger drains uniformly
                            (
                                None,
                                0.0,
                                FeedbackV2::discard(td.frame.batch_id, td.seq, td.epoch),
                                false,
                            )
                        } else {
                            let tv =
                                self.cloud.verify_tree_ref(td.tree_ref(), cloud_prev, self.cfg.temp)?;
                            // the epoch moves unless the full trunk held:
                            // any divergence invalidates the speculative
                            // continuation drafted past the trunk tip
                            if !tv.full_trunk {
                                cloud_epoch = cloud_epoch.wrapping_add(1);
                            }
                            cloud_prev = *tv.verdict.committed.last().unwrap();
                            let llm = match self.cfg.timing {
                                TimingMode::Measured => tv.verdict.t_llm,
                                // one verify window per root-to-leaf path
                                TimingMode::Modeled { llm_call_s, .. } => {
                                    llm_call_s * leaf_count as f64
                                }
                            };
                            let mut fb = tv.verdict.feedback_v2(Vec::new());
                            fb.exts.push(Ext::TreeAck(TreeAck {
                                seq: td.seq,
                                epoch: td.epoch,
                                discard: false,
                                resampled: tv.verdict.rejected,
                                node: tv.survivor,
                                depth: tv.depth as u8,
                            }));
                            (Some(tv.verdict), llm, fb, tv.full_trunk)
                        }
                    }
                    other => {
                        bail!("expected a draft frame on the uplink, got {}", other.name())
                    }
                };
                let verify_start = delivered_at.max(cloud_free);
                let verify_done = verify_start + llm_time;
                cloud_free = verify_done;
                if let Some(v) = &verdict {
                    let vwindow = tree_nodes + 1;
                    self.tracer
                        .emit(verify_start, ACTOR_CLOUD, || TraceData::VerifyStart {
                            window: vwindow,
                        });
                    let (accepted, rejected) = (v.accepted, v.rejected);
                    self.tracer
                        .emit(verify_done, ACTOR_CLOUD, || TraceData::VerifyEnd {
                            accepted,
                            rejected,
                        });
                }

                // ---- downlink feedback ----------------------------------
                let down_frame = Frame::Feedback(fb_out);
                let mut d_down = self.transport.send_frame(
                    Direction::Down,
                    &down_frame,
                    &mut self.edge.wire,
                    0.0,
                )?;
                downlink_bits += d_down.bits as u64;
                let fb_air_s = d_down.bits as f64 / self.transport.link.cfg.downlink_bps;
                let mut fb_start = verify_done.max(down_busy);
                down_busy = fb_start + fb_air_s;
                // ---- downlink loss recovery (never entered at loss = 0).
                // A lost feedback strands the edge: it times out, re-sends
                // the draft — a duplicate the cloud recognizes by sequence
                // number and answers from its cached feedback without
                // re-verifying — and waits again.  Either leg of that
                // exchange can be lost too, so the loop is bounded like
                // the uplink's.
                let mut down_attempt = 0u32;
                // the edge's timeout clock starts when the lost feedback
                // would have arrived
                let mut deadline = fb_start + d_down.latency_s();
                while self.transport.last_send_lost() {
                    down_attempt += 1;
                    if down_attempt > self.cfg.max_retransmits {
                        bail!(
                            "feedback for seq {seq} lost beyond recovery \
                             ({} duplicate-draft retries)",
                            self.cfg.max_retransmits
                        );
                    }
                    retransmits += 1;
                    let act_at = deadline + self.cfg.loss_timeout_s;
                    let attempt = down_attempt;
                    self.tracer.emit(act_at, 0, || TraceData::Retransmit {
                        dir: Dir::Down,
                        batch_seq: seq,
                        attempt,
                    });
                    // duplicate draft up (itself subject to loss)
                    let d_dup = self.transport.send_frame(
                        Direction::Up,
                        &up_frame,
                        &mut self.edge.wire,
                        0.0,
                    )?;
                    uplink_bits += d_dup.bits as u64;
                    let dup_start = act_at.max(up_busy);
                    up_busy =
                        dup_start + d_dup.bits as f64 / self.transport.link.cfg.uplink_bps;
                    t_recovery += self.cfg.loss_timeout_s + d_dup.latency_s();
                    if self.transport.last_send_lost() {
                        // the duplicate died too: time out again from its
                        // (never-observed) delivery time
                        deadline = dup_start + d_dup.latency_s();
                        continue;
                    }
                    // the cloud drains the duplicate and re-sends the
                    // cached feedback
                    let _ = self.transport.recv_frame_view(
                        Direction::Up,
                        &mut self.edge.wire,
                        &mut arena,
                    )?;
                    d_down = self.transport.send_frame(
                        Direction::Down,
                        &down_frame,
                        &mut self.edge.wire,
                        0.0,
                    )?;
                    downlink_bits += d_down.bits as u64;
                    fb_start = (dup_start + d_dup.latency_s()).max(down_busy);
                    down_busy = fb_start + fb_air_s;
                    deadline = fb_start + d_down.latency_s();
                }
                let down_time = d_down.latency_s();
                let arrive_at = fb_start + down_time;
                self.tracer.emit(fb_start, ACTOR_CLOUD, || TraceData::FrameTx {
                    dir: Dir::Down,
                    frame: "feedback",
                    bits: d_down.bits,
                    air_s: fb_air_s,
                });
                self.tracer.emit(arrive_at, 0, || TraceData::FrameRx {
                    dir: Dir::Down,
                    frame: "feedback",
                    bits: d_down.bits,
                });
                // the feedback outlives this round in the in-flight ledger,
                // so it is the one piece promoted to an owned frame — but
                // still parsed through the session arena, not a fresh one
                let fb = match self.transport.recv_frame_view(
                    Direction::Down,
                    &mut self.edge.wire,
                    &mut arena,
                )? {
                    FrameView::Feedback(f) => f.to_feedback(),
                    other => bail!("expected a Feedback frame, got {}", other.name()),
                };

                in_flight.push_back(InFlightBatch {
                    seq,
                    ctx_before,
                    drafted: l,
                    tree_nodes,
                    trunk,
                    full_trunk,
                    dist_bits,
                    mean_k,
                    mean_alpha,
                    alphas: node_alphas,
                    tvs: node_tvs,
                    knobs,
                    frame_bits: d_up.bits,
                    feedback_bits: d_down.bits,
                    queue_wait_s,
                    t_slm: slm_time,
                    t_uplink: up_time,
                    t_llm: llm_time,
                    t_downlink: down_time,
                    verdict,
                    fb,
                    arrive_at,
                });
                speculated += l;
                continue;
            }

            // ---- window full / nothing left to draft: consume the oldest
            // feedback (FIFO downlink: strictly by sequence) --------------
            let Some(p) = in_flight.pop_front() else { break };
            let arrive = p.arrive_at.max(last_arrival);
            last_arrival = arrive;
            t_edge = t_edge.max(arrive);
            speculated -= p.drafted;
            if let Some(bits) = p.fb.grant() {
                self.tracer.emit(arrive, 0, || TraceData::GrantIssued { bits });
            }

            match p.verdict {
                None => {
                    // stale frame, discarded by the cloud: retire the seq;
                    // its wire time and bits were still spent
                    debug_assert!(pipelined);
                    debug_assert_eq!(p.fb.acked_seq().map(|(s, _)| s), Some(p.seq));
                    self.tracer.emit(arrive, 0, || TraceData::FeedbackApplied {
                        batch_seq: p.seq,
                        accepted: 0,
                        discarded: true,
                    });
                    discarded += 1;
                    t_slm += p.t_slm;
                    t_up += p.t_uplink;
                    t_down += p.t_downlink;
                    self.control.feedback(&BatchOutcome {
                        drafted: p.drafted,
                        accepted: 0,
                        rejected: false,
                        frame_bits: p.frame_bits,
                        t_uplink_s: p.t_uplink,
                        queue_wait_s: p.queue_wait_s,
                        congestion: p.fb.congestion(),
                        grant_bits: p.fb.grant(),
                        discarded: true,
                        tree_nodes: p.tree_nodes,
                    });
                }
                Some(verdict) => {
                    let accepted = p.fb.accepted as usize;
                    self.tracer.emit(arrive, 0, || TraceData::FeedbackApplied {
                        batch_seq: p.seq,
                        accepted,
                        discarded: false,
                    });
                    if let Some(a) = p.fb.tree_ack() {
                        let (node, depth, resampled) = (a.node, a.depth as usize, a.resampled);
                        self.tracer.emit(arrive, 0, || TraceData::TreeSurvivor {
                            node,
                            depth,
                            resampled,
                        });
                    }
                    // ---- rejection attribution (paper's decomposition) --
                    // distortion share = TV(q, q̂) / r̂ at the rejection
                    // position, capped at 1: the compression-induced part
                    // of the dense-vs-compressed rejection estimate.  The
                    // remainder is SLM-LLM mismatch.
                    if let Some((pos, rhat)) = verdict.reject_at {
                        let alpha = p.alphas.get(pos).copied().unwrap_or(0.0) as f64;
                        let tv = p.tvs.get(pos).copied().unwrap_or(0.0) as f64;
                        let distortion = (tv / rhat.max(1e-12)).min(1.0);
                        let mismatch = 1.0 - distortion;
                        if distortion > 0.5 {
                            reject_distortion += 1;
                        } else {
                            reject_mismatch += 1;
                        }
                        reject_mass_distortion += distortion;
                        reject_mass_mismatch += mismatch;
                        let batch_seq = p.seq;
                        self.tracer.emit(arrive, 0, || TraceData::RejectAttrib {
                            batch_seq,
                            pos,
                            alpha,
                            tv,
                            rhat,
                            mismatch,
                            distortion,
                        });
                    }
                    if let Some(trunk) = &p.trunk {
                        // token tree: branch the rollback to the surviving
                        // node instead of the epoch root
                        debug_assert_eq!(p.fb.tree_ack().map(|a| a.seq), Some(p.seq));
                        let survivor =
                            &verdict.committed[..verdict.committed.len()
                                - verdict.rejected as usize];
                        let full = self.edge.apply_feedback_tree(
                            p.ctx_before,
                            trunk,
                            survivor,
                            verdict.rejected,
                            p.fb.new_token,
                        )?;
                        debug_assert_eq!(full, p.full_trunk, "edge/cloud trunk verdicts agree");
                        if !full {
                            // any divergence from the trunk invalidates the
                            // continuation drafted past its tip
                            edge_epoch = edge_epoch.wrapping_add(1);
                            exhausted = false; // rollback freed context room
                            let epoch = edge_epoch;
                            self.tracer.emit(arrive, 0, || TraceData::EpochRollback { epoch });
                        }
                    } else if pipelined {
                        debug_assert_eq!(p.fb.ack().map(|a| a.seq), Some(p.seq));
                        self.edge.apply_feedback_pipelined(
                            p.ctx_before,
                            p.drafted,
                            accepted,
                            p.fb.new_token,
                        )?;
                        if accepted < p.drafted {
                            // rejection: the rollback above discarded every
                            // speculated token past the accepted prefix; the
                            // epoch bump makes the cloud discard the
                            // corresponding in-flight frames
                            edge_epoch = edge_epoch.wrapping_add(1);
                            exhausted = false; // rollback freed context room
                            let epoch = edge_epoch;
                            self.tracer.emit(arrive, 0, || TraceData::EpochRollback { epoch });
                        }
                    } else {
                        self.edge.apply_feedback(
                            p.ctx_before,
                            p.drafted,
                            accepted,
                            p.fb.new_token,
                        )?;
                    }
                    self.seq.extend_from_slice(&verdict.committed);

                    // ---- control plane: fold the round's ledger back in -
                    // (per-path quantities: trunk drafted, path accepted)
                    self.control.feedback(&BatchOutcome {
                        drafted: p.drafted,
                        accepted: verdict.accepted,
                        rejected: verdict.rejected,
                        frame_bits: p.frame_bits,
                        t_uplink_s: p.t_uplink,
                        queue_wait_s: p.queue_wait_s,
                        congestion: p.fb.congestion(),
                        grant_bits: p.fb.grant(),
                        discarded: false,
                        tree_nodes: p.tree_nodes,
                    });

                    // consistency: edge and cloud contexts must match the
                    // canonical sequence whenever nothing is speculated
                    if !pipelined {
                        debug_assert_eq!(self.edge.context_len(), self.seq.len());
                        debug_assert_eq!(self.cloud.context_len(), self.seq.len());
                    } else if in_flight.is_empty() {
                        debug_assert_eq!(self.edge.context_len(), self.seq.len());
                    }

                    if verdict.rejected {
                        n_rej += 1;
                    }
                    t_slm += p.t_slm;
                    t_up += p.t_uplink;
                    t_llm += p.t_llm;
                    t_down += p.t_downlink;

                    let round = batches.len() as u64;
                    batches.push(BatchRecord {
                        drafted: p.drafted,
                        accepted: verdict.accepted,
                        rejected: verdict.rejected,
                        dist_bits: p.dist_bits,
                        frame_bits: p.frame_bits,
                        feedback_bits: p.feedback_bits,
                        mean_k: p.mean_k,
                        mean_alpha: p.mean_alpha,
                        tree_nodes: p.tree_nodes,
                        knobs: KnobPoint::from_knobs(round, &p.knobs),
                        t_slm: p.t_slm,
                        t_uplink: p.t_uplink,
                        t_llm: p.t_llm,
                        t_downlink: p.t_downlink,
                    });
                }
            }
        }

        // the alternating protocol serializes the four stages, so their
        // sum (plus any loss-recovery stalls) IS the end-to-end time
        // (bit-identical to the v2 loop at loss = 0, where t_recovery is
        // exactly 0.0); a pipelined run overlaps stages and reports the
        // makespan instead, whose busy-until clocks already absorbed the
        // recovery delays
        let total_time_s = if pipelined {
            t_edge
        } else {
            t_slm + t_up + t_llm + t_down + t_recovery
        };
        let mut res = self.assemble(
            prompt.len(),
            batches,
            n_rej,
            discarded,
            total_time_s,
            t_slm,
            t_up,
            t_llm,
            t_down,
            uplink_bits,
            downlink_bits,
            &hs,
        );
        res.reject_mismatch = reject_mismatch;
        res.reject_distortion = reject_distortion;
        res.reject_mass_mismatch = reject_mass_mismatch;
        res.reject_mass_distortion = reject_mass_distortion;
        res.retransmits = retransmits;
        res.loss_resyncs = loss_resyncs;
        res.t_recovery_s = t_recovery;
        Ok(res)
    }

    /// The frozen protocol-v2 strictly alternating loop, exactly as it
    /// shipped before pipelining.  Kept as the regression reference:
    /// `tests/pipelining.rs` pins `run()` at `pipeline_depth = 1` to be
    /// bit-identical to this method (tokens, ledgers, and every latency
    /// component).  Not used by any production path.
    pub fn run_reference_lockstep(&mut self, prompt: &[u16]) -> Result<SessionResult> {
        let hs = self.start_and_handshake(prompt)?;
        let mut uplink_bits = hs.up_bits;
        let mut downlink_bits = hs.down_bits;
        let (mut t_slm, mut t_llm) = (0.0, 0.0);
        let mut t_up = hs.t_up;
        let mut t_down = hs.t_down;

        let mut batches = Vec::new();
        let mut n_rej = 0usize;

        while self.seq.len() - prompt.len() < self.cfg.max_new_tokens
            && self.room_left()
        {
            let ctx_before = self.seq.len();

            // ---- control plane: knobs for this round --------------------
            let knobs = self.control.begin_batch();

            // ---- edge: draft under budget -------------------------------
            let remaining =
                self.cfg.max_new_tokens - (self.seq.len() - prompt.len());
            let drafted = self.edge.draft_batch_knobs(self.cfg.temp, remaining, &knobs)?;
            let l = drafted.frame.tokens.len();
            if l == 0 {
                break; // context exhausted
            }
            let slm_time = match self.cfg.timing {
                TimingMode::Measured => drafted.t_slm,
                TimingMode::Modeled { slm_step_s, .. } => slm_step_s * l as f64,
            };

            // ---- uplink: the transport encodes + charges the link -------
            // (the frame is moved, not cloned: everything the record
            // keeps — dist_bits, ks, t_slm — lives outside it)
            let up_frame = Frame::Draft(drafted.frame);
            let d_up = self.transport.send_frame(
                Direction::Up,
                &up_frame,
                &mut self.edge.wire,
                0.0,
            )?;
            let up_time = d_up.latency_s();
            uplink_bits += d_up.bits as u64;

            // ---- cloud: decode frame + verify ---------------------------
            // (decode from the actual wire bytes: the format is exercised
            // on every batch, not just in codec tests)
            let decoded = match self.transport.recv_frame(Direction::Up, &mut self.edge.wire)? {
                Frame::Draft(f) => f,
                other => bail!("expected a Draft frame on the uplink, got {}", other.name()),
            };
            let prev = *self.seq.last().unwrap();
            let verdict = self.cloud.verify_with_prev(&decoded, prev, self.cfg.temp)?;
            let llm_time = match self.cfg.timing {
                TimingMode::Measured => verdict.t_llm,
                TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
            };

            // ---- downlink feedback (v2; no extensions on a private link)
            let d_down = self.transport.send_frame(
                Direction::Down,
                &Frame::Feedback(verdict.feedback_v2(Vec::new())),
                &mut self.edge.wire,
                0.0,
            )?;
            let down_time = d_down.latency_s();
            downlink_bits += d_down.bits as u64;
            let fb = match self.transport.recv_frame(Direction::Down, &mut self.edge.wire)? {
                Frame::Feedback(f) => f,
                other => bail!("expected a Feedback frame, got {}", other.name()),
            };

            // ---- edge sync + conformal backtrack ------------------------
            self.edge.apply_feedback(ctx_before, l, fb.accepted as usize, fb.new_token)?;
            self.seq.extend_from_slice(&verdict.committed);

            // ---- control plane: fold the round's ledger back in ---------
            self.control.feedback(&BatchOutcome {
                drafted: l,
                accepted: verdict.accepted,
                rejected: verdict.rejected,
                frame_bits: d_up.bits,
                t_uplink_s: up_time,
                queue_wait_s: 0.0, // private link: no shared-uplink queue
                congestion: fb.congestion(),
                grant_bits: fb.grant(),
                discarded: false,
                tree_nodes: l,
            });

            // consistency: edge and cloud contexts must match ours
            debug_assert_eq!(self.edge.context_len(), self.seq.len());
            debug_assert_eq!(self.cloud.context_len(), self.seq.len());

            if verdict.rejected {
                n_rej += 1;
            }
            t_slm += slm_time;
            t_up += up_time;
            t_llm += llm_time;
            t_down += down_time;

            let round = batches.len() as u64;
            batches.push(BatchRecord {
                drafted: l,
                accepted: verdict.accepted,
                rejected: verdict.rejected,
                dist_bits: drafted.dist_bits.iter().sum(),
                frame_bits: d_up.bits,
                feedback_bits: d_down.bits,
                mean_k: drafted.ks.iter().sum::<usize>() as f64 / l as f64,
                mean_alpha: drafted.alphas.iter().map(|&a| a as f64).sum::<f64>() / l as f64,
                tree_nodes: l,
                knobs: KnobPoint::from_knobs(round, &knobs),
                t_slm: slm_time,
                t_uplink: up_time,
                t_llm: llm_time,
                t_downlink: down_time,
            });
        }

        Ok(self.assemble(
            prompt.len(),
            batches,
            n_rej,
            0,
            t_slm + t_up + t_llm + t_down,
            t_slm,
            t_up,
            t_llm,
            t_down,
            uplink_bits,
            downlink_bits,
            &hs,
        ))
    }

    /// Shared result assembly (conformal certificate gating + ledgers).
    #[allow(clippy::too_many_arguments)]
    fn assemble(
        &self,
        prompt_len: usize,
        batches: Vec<BatchRecord>,
        n_rej: usize,
        discarded: usize,
        total_time_s: f64,
        t_slm: f64,
        t_up: f64,
        t_llm: f64,
        t_down: f64,
        uplink_bits: u64,
        downlink_bits: u64,
        hs: &HandshakeLedger,
    ) -> SessionResult {
        // AIMD pins a top-K sparsifier on every token, so the conformal
        // controller — though it kept observing — was never in control:
        // reporting its Theorem 2 certificate would be misleading
        let conformal = if matches!(self.cfg.adaptive, AdaptiveMode::Aimd { .. }) {
            None
        } else {
            self.edge.conformal.as_ref()
        };
        let mean_alpha = if batches.is_empty() {
            0.0
        } else {
            batches.iter().map(|b| b.mean_alpha).sum::<f64>() / batches.len() as f64
        };
        SessionResult {
            prompt_len,
            tokens: self.seq.clone(),
            batches,
            n_rej,
            pipeline_depth: self.cfg.pipeline_depth.max(1),
            tree_branching: self.cfg.tree_branching.max(1),
            discarded_batches: discarded,
            retransmits: 0,
            loss_resyncs: 0,
            t_recovery_s: 0.0,
            total_time_s,
            t_slm_s: t_slm,
            t_uplink_s: t_up,
            t_llm_s: t_llm,
            t_downlink_s: t_down,
            uplink_bits,
            downlink_bits,
            handshake_uplink_bits: hs.up_bits,
            handshake_downlink_bits: hs.down_bits,
            conformal_empirical_alpha: conformal.map(|c| c.empirical_alpha()),
            conformal_bound: conformal.map(|c| c.theorem2_bound()),
            conformal_t: conformal.map(|c| c.t()),
            reject_mismatch: 0,
            reject_distortion: 0,
            reject_mass_mismatch: 0.0,
            reject_mass_distortion: 0.0,
            mean_alpha,
        }
    }

    fn room_left(&self) -> bool {
        self.room_left_at(self.seq.len())
    }

    /// Room check at an arbitrary context length (committed + speculated):
    /// need room for a full verify window on the target and a token on
    /// the draft side.
    fn room_left_at(&self, ctx: usize) -> bool {
        ctx + self.cfg.max_batch_drafts + 2 < self.cloud.target.max_len()
            && ctx + self.cfg.max_batch_drafts + 2 < self.edge_max_len()
    }

    fn edge_max_len(&self) -> usize {
        self.edge.draft.max_len()
    }
}

/// One-time handshake ledger entries (bits + one-way latencies, plus
/// any loss-recovery spend the exchange needed; both zero at loss = 0).
struct HandshakeLedger {
    up_bits: u64,
    down_bits: u64,
    t_up: f64,
    t_down: f64,
    retransmits: u64,
    t_recovery: f64,
}

/// One unacknowledged speculative batch in the session engine's
/// in-flight ledger.  The cloud half (verdict, feedback frame, arrival
/// time) is evaluated eagerly at send time; the edge acts on it only
/// when the loop's virtual clock reaches `arrive_at`.
struct InFlightBatch {
    seq: u16,
    ctx_before: usize,
    /// per-path drafted basis: the trunk length for tree frames
    drafted: usize,
    /// wire nodes the frame carried (== drafted for linear frames)
    tree_nodes: usize,
    /// token-tree trunk values (None: linear frame)
    trunk: Option<Vec<u16>>,
    /// cloud-side verdict on whether the full trunk held (tree frames)
    full_trunk: bool,
    dist_bits: usize,
    mean_k: f64,
    mean_alpha: f64,
    /// per-node dropped mass (edge side; never rides the wire)
    alphas: Vec<f32>,
    /// per-node compression distortion TV(q, q̂) (edge side)
    tvs: Vec<f32>,
    knobs: Knobs,
    frame_bits: usize,
    feedback_bits: usize,
    /// time the frame waited for the serialized uplink transmitter
    queue_wait_s: f64,
    t_slm: f64,
    t_uplink: f64,
    t_llm: f64,
    t_downlink: f64,
    /// None: the cloud discarded the frame as stale
    verdict: Option<Verdict>,
    fb: FeedbackV2,
    /// virtual time the feedback reaches the edge
    arrive_at: f64,
}

/// Cloud-only autoregressive baseline over the same latency model: the
/// prompt goes up once, every generated token comes back down.
pub struct ArBaseline<T: TargetLm> {
    pub cloud: CloudNode<T>,
    pub link: SimulatedLink,
    pub temp: f32,
    pub timing: TimingMode,
}

impl<T: TargetLm> ArBaseline<T> {
    pub fn new(target: T, link: SimulatedLink, temp: f32, seed: u64,
               timing: TimingMode) -> Self {
        ArBaseline {
            cloud: CloudNode::new(target, seed ^ 0xA2),
            link,
            temp,
            timing,
        }
    }

    pub fn run(&mut self, prompt: &[u16], max_new_tokens: usize) -> Result<SessionResult> {
        self.cloud.start(prompt)?;
        let mut seq = prompt.to_vec();
        // prompt uplink: raw bytes (8 bits/token) once
        let mut t_up = self.link.send_uplink(prompt.len() * 8);
        let mut t_llm = 0.0;
        let mut t_down = 0.0;
        let mut downlink_bits = 0u64;
        while seq.len() - prompt.len() < max_new_tokens
            && seq.len() + 2 < self.cloud.target.max_len()
        {
            let (tok, t) = self.cloud.decode_one(self.temp)?;
            t_llm += match self.timing {
                TimingMode::Measured => t,
                TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
            };
            t_down += self.link.send_downlink(8);
            downlink_bits += 8;
            seq.push(tok);
        }
        Ok(SessionResult {
            prompt_len: prompt.len(),
            tokens: seq,
            batches: Vec::new(),
            n_rej: 0,
            pipeline_depth: 1,
            tree_branching: 1,
            discarded_batches: 0,
            retransmits: 0,
            loss_resyncs: 0,
            t_recovery_s: 0.0,
            total_time_s: t_up + t_llm + t_down,
            t_slm_s: 0.0,
            t_uplink_s: t_up,
            t_llm_s: t_llm,
            t_downlink_s: t_down,
            uplink_bits: (prompt.len() * 8) as u64,
            downlink_bits,
            handshake_uplink_bits: 0,
            handshake_downlink_bits: 0,
            conformal_empirical_alpha: None,
            conformal_bound: None,
            conformal_t: None,
            reject_mismatch: 0,
            reject_distortion: 0,
            reject_mass_mismatch: 0.0,
            reject_mass_distortion: 0.0,
            mean_alpha: 0.0,
        })
    }
}
