//! SdSession: one request's full edge–channel–cloud speculative-decoding
//! loop, with the latency ledger the paper's figures are built from.
//!
//! Latency model (matching [22]'s decomposition, §4 of the paper):
//!   total = handshake (Hello up + HelloAck down) + sum over batches of
//!     t_slm (measured draft compute) + t_uplink (simulated: frame bits /
//!     bandwidth + propagation) + t_llm (measured verify compute) +
//!     t_downlink (simulated feedback).
//! Compute can optionally be *modeled* (fixed per-call costs) for
//! hardware-independent, exactly reproducible sweeps — used by the
//! synthetic-backend benches; PJRT benches default to measured.
//!
//! Since protocol v2 the session speaks typed frames through a
//! [`LinkTransport`]: drafts and feedback are encoded exactly once, by
//! the transport, and the cloud side decodes the same bytes — there is
//! no codec call in the session itself.  The one-time handshake bits are
//! ledgered in `uplink_bits`/`downlink_bits` (broken out in
//! `SessionResult` so bit-accounting tests stay exact).

use anyhow::{bail, Result};

use crate::channel::SimulatedLink;
use crate::cloud::CloudNode;
use crate::control::{AdaptiveMode, BatchOutcome, ControlLoop, KnobPoint};
use crate::edge::EdgeNode;
use crate::model::{DraftLm, TargetLm};
use crate::protocol::{negotiate, Direction, Frame, LinkTransport, Transport};
use crate::sqs::Policy;
use crate::util::stats::Summary;

/// How compute time enters the latency ledger.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TimingMode {
    /// wall-clock of the actual PJRT/synthetic calls
    Measured,
    /// fixed seconds per SLM draft step and per LLM verify call
    Modeled { slm_step_s: f64, llm_call_s: f64 },
}

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub policy: Policy,
    pub temp: f32,
    pub ell: u32,
    /// per-batch uplink budget B, in bits (paper: 5000)
    pub budget_bits: usize,
    pub max_new_tokens: usize,
    pub max_batch_drafts: usize,
    pub seed: u64,
    pub timing: TimingMode,
    /// link-adaptive control plane (Off = today's fixed knobs, bit-exact)
    pub adaptive: AdaptiveMode,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.8,
            ell: 100,
            budget_bits: 5000,
            max_new_tokens: 64,
            max_batch_drafts: 15,
            seed: 0,
            timing: TimingMode::Measured,
            adaptive: AdaptiveMode::Off,
        }
    }
}

/// Per-batch record (diagnostics, figure generation, knob traces).
#[derive(Clone, Debug)]
pub struct BatchRecord {
    pub drafted: usize,
    pub accepted: usize,
    pub rejected: bool,
    pub dist_bits: usize,
    pub frame_bits: usize,
    /// downlink feedback frame size, bits (v2: varies with extensions)
    pub feedback_bits: usize,
    pub mean_k: f64,
    /// the control-plane knobs (K^t, ℓ^t, B^t) in force this round
    pub knobs: KnobPoint,
    pub t_slm: f64,
    pub t_uplink: f64,
    pub t_llm: f64,
    pub t_downlink: f64,
}

/// Aggregated result of a session.
#[derive(Clone, Debug)]
pub struct SessionResult {
    pub prompt_len: usize,
    pub tokens: Vec<u16>,
    pub batches: Vec<BatchRecord>,
    pub n_rej: usize,
    pub total_time_s: f64,
    pub t_slm_s: f64,
    pub t_uplink_s: f64,
    pub t_llm_s: f64,
    pub t_downlink_s: f64,
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    /// one-time Hello bits (included in `uplink_bits`)
    pub handshake_uplink_bits: u64,
    /// one-time HelloAck bits (included in `downlink_bits`)
    pub handshake_downlink_bits: u64,
    pub conformal_empirical_alpha: Option<f64>,
    pub conformal_bound: Option<f64>,
    pub conformal_t: Option<u64>,
}

impl SessionResult {
    pub fn new_tokens(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    /// The paper's resampling-rate metric: N_rej / #batches.
    pub fn resampling_rate(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.n_rej as f64 / self.batches.len() as f64
        }
    }

    /// Fraction of drafted tokens accepted.
    pub fn acceptance_rate(&self) -> f64 {
        let drafted: usize = self.batches.iter().map(|b| b.drafted).sum();
        let accepted: usize = self.batches.iter().map(|b| b.accepted).sum();
        if drafted == 0 { 0.0 } else { accepted as f64 / drafted as f64 }
    }

    pub fn mean_k(&self) -> f64 {
        let mut s = Summary::new();
        for b in &self.batches {
            s.add(b.mean_k);
        }
        s.mean()
    }

    pub fn bits_per_token(&self) -> f64 {
        let n = self.new_tokens();
        if n == 0 { 0.0 } else { self.uplink_bits as f64 / n as f64 }
    }

    /// Mean wire bits per speculative round — the control plane's AIMD
    /// budget basis (0 for the batchless AR baseline).
    pub fn mean_bits_per_round(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.uplink_bits as f64 / self.batches.len() as f64
        }
    }

    pub fn latency_per_token(&self) -> f64 {
        let n = self.new_tokens();
        if n == 0 { 0.0 } else { self.total_time_s / n as f64 }
    }
}

/// One request, one edge, one cloud, one link.
pub struct SdSession<D: DraftLm, T: TargetLm> {
    pub edge: EdgeNode<D>,
    pub cloud: CloudNode<T>,
    /// typed frame channel over the simulated link
    pub transport: LinkTransport,
    pub cfg: SessionConfig,
    /// link-adaptive control plane, consulted once per batch
    pub control: ControlLoop,
    /// canonical committed sequence (prompt + verified tokens)
    seq: Vec<u16>,
}

impl<D: DraftLm, T: TargetLm> SdSession<D, T> {
    pub fn new(draft: D, target: T, link: SimulatedLink, cfg: SessionConfig) -> Self {
        let vocab = draft.vocab();
        let mut edge = EdgeNode::new(
            draft,
            cfg.policy,
            cfg.ell,
            cfg.budget_bits,
            cfg.max_batch_drafts,
            cfg.seed ^ 0xE,
        );
        // runtime-varying K needs the per-token-K wire scheme
        if matches!(cfg.adaptive, AdaptiveMode::Aimd { .. }) {
            edge.use_adaptive_scheme();
        }
        let control = ControlLoop::for_session(
            cfg.adaptive,
            cfg.policy,
            cfg.max_batch_drafts,
            cfg.budget_bits,
            vocab,
        );
        let cloud = CloudNode::new(target, cfg.seed ^ 0xC);
        SdSession {
            edge,
            cloud,
            transport: LinkTransport::new(link),
            cfg,
            control,
            seq: Vec::new(),
        }
    }

    /// Run the speculative-decoding loop to completion.
    pub fn run(&mut self, prompt: &[u16]) -> Result<SessionResult> {
        self.edge.start(prompt)?;
        self.cloud.start(prompt)?;
        self.seq = prompt.to_vec();

        // ---- handshake: negotiate version + codec parameters ------------
        // The edge advertises its codec config; the cloud validates and
        // acks.  Both frames ride the simulated link, so their bits and
        // latency are in the ledger like every other wire event.
        let hello = self.edge.wire.hello().map_err(|e| anyhow::anyhow!("handshake: {e}"))?;
        let d_hello = self.transport.send_frame(
            Direction::Up,
            &Frame::Hello(hello),
            &mut self.edge.wire,
            0.0,
        )?;
        let heard = match self.transport.recv_frame(Direction::Up, &mut self.edge.wire)? {
            Frame::Hello(h) => h,
            other => bail!("handshake: expected Hello on the uplink, got {}", other.name()),
        };
        let ack = negotiate(&heard).map_err(|e| anyhow::anyhow!("handshake rejected: {e}"))?;
        let d_ack = self.transport.send_frame(
            Direction::Down,
            &Frame::HelloAck(ack),
            &mut self.edge.wire,
            0.0,
        )?;
        let ack = match self.transport.recv_frame(Direction::Down, &mut self.edge.wire)? {
            Frame::HelloAck(a) => a,
            other => bail!("handshake: expected HelloAck, got {}", other.name()),
        };
        if !ack.ok {
            bail!("handshake: cloud rejected the session");
        }
        if !self.edge.wire.matches(&ack) {
            bail!("handshake: ack does not match the advertised codec config");
        }

        let hs_up = d_hello.bits as u64;
        let hs_down = d_ack.bits as u64;
        let mut uplink_bits = hs_up;
        let mut downlink_bits = hs_down;
        let (mut t_slm, mut t_llm) = (0.0, 0.0);
        let mut t_up = d_hello.latency_s();
        let mut t_down = d_ack.latency_s();

        let mut batches = Vec::new();
        let mut n_rej = 0usize;

        while self.seq.len() - prompt.len() < self.cfg.max_new_tokens
            && self.room_left()
        {
            let ctx_before = self.seq.len();

            // ---- control plane: knobs for this round --------------------
            let knobs = self.control.begin_batch();

            // ---- edge: draft under budget -------------------------------
            let remaining =
                self.cfg.max_new_tokens - (self.seq.len() - prompt.len());
            let drafted = self.edge.draft_batch_knobs(self.cfg.temp, remaining, &knobs)?;
            let l = drafted.frame.tokens.len();
            if l == 0 {
                break; // context exhausted
            }
            let slm_time = match self.cfg.timing {
                TimingMode::Measured => drafted.t_slm,
                TimingMode::Modeled { slm_step_s, .. } => slm_step_s * l as f64,
            };

            // ---- uplink: the transport encodes + charges the link -------
            // (the frame is moved, not cloned: everything the record
            // keeps — dist_bits, ks, t_slm — lives outside it)
            let up_frame = Frame::Draft(drafted.frame);
            let d_up = self.transport.send_frame(
                Direction::Up,
                &up_frame,
                &mut self.edge.wire,
                0.0,
            )?;
            let up_time = d_up.latency_s();
            uplink_bits += d_up.bits as u64;

            // ---- cloud: decode frame + verify ---------------------------
            // (decode from the actual wire bytes: the format is exercised
            // on every batch, not just in codec tests)
            let decoded = match self.transport.recv_frame(Direction::Up, &mut self.edge.wire)? {
                Frame::Draft(f) => f,
                other => bail!("expected a Draft frame on the uplink, got {}", other.name()),
            };
            let prev = *self.seq.last().unwrap();
            let verdict = self.cloud.verify_with_prev(&decoded, prev, self.cfg.temp)?;
            let llm_time = match self.cfg.timing {
                TimingMode::Measured => verdict.t_llm,
                TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
            };

            // ---- downlink feedback (v2; no extensions on a private link)
            let d_down = self.transport.send_frame(
                Direction::Down,
                &Frame::Feedback(verdict.feedback_v2(Vec::new())),
                &mut self.edge.wire,
                0.0,
            )?;
            let down_time = d_down.latency_s();
            downlink_bits += d_down.bits as u64;
            let fb = match self.transport.recv_frame(Direction::Down, &mut self.edge.wire)? {
                Frame::Feedback(f) => f,
                other => bail!("expected a Feedback frame, got {}", other.name()),
            };

            // ---- edge sync + conformal backtrack ------------------------
            self.edge.apply_feedback(ctx_before, l, fb.accepted as usize, fb.new_token)?;
            self.seq.extend_from_slice(&verdict.committed);

            // ---- control plane: fold the round's ledger back in ---------
            self.control.feedback(&BatchOutcome {
                drafted: l,
                accepted: verdict.accepted,
                rejected: verdict.rejected,
                frame_bits: d_up.bits,
                t_uplink_s: up_time,
                queue_wait_s: 0.0, // private link: no shared-uplink queue
                congestion: fb.congestion(),
                grant_bits: fb.grant(),
            });

            // consistency: edge and cloud contexts must match ours
            debug_assert_eq!(self.edge.context_len(), self.seq.len());
            debug_assert_eq!(self.cloud.context_len(), self.seq.len());

            if verdict.rejected {
                n_rej += 1;
            }
            t_slm += slm_time;
            t_up += up_time;
            t_llm += llm_time;
            t_down += down_time;

            let round = batches.len() as u64;
            batches.push(BatchRecord {
                drafted: l,
                accepted: verdict.accepted,
                rejected: verdict.rejected,
                dist_bits: drafted.dist_bits.iter().sum(),
                frame_bits: d_up.bits,
                feedback_bits: d_down.bits,
                mean_k: drafted.ks.iter().sum::<usize>() as f64 / l as f64,
                knobs: KnobPoint::from_knobs(round, &knobs),
                t_slm: slm_time,
                t_uplink: up_time,
                t_llm: llm_time,
                t_downlink: down_time,
            });
        }

        // AIMD pins a top-K sparsifier on every token, so the conformal
        // controller — though it kept observing — was never in control:
        // reporting its Theorem 2 certificate would be misleading
        let conformal = if matches!(self.cfg.adaptive, AdaptiveMode::Aimd { .. }) {
            None
        } else {
            self.edge.conformal.as_ref()
        };
        Ok(SessionResult {
            prompt_len: prompt.len(),
            tokens: self.seq.clone(),
            batches,
            n_rej,
            total_time_s: t_slm + t_up + t_llm + t_down,
            t_slm_s: t_slm,
            t_uplink_s: t_up,
            t_llm_s: t_llm,
            t_downlink_s: t_down,
            uplink_bits,
            downlink_bits,
            handshake_uplink_bits: hs_up,
            handshake_downlink_bits: hs_down,
            conformal_empirical_alpha: conformal.map(|c| c.empirical_alpha()),
            conformal_bound: conformal.map(|c| c.theorem2_bound()),
            conformal_t: conformal.map(|c| c.t()),
        })
    }

    fn room_left(&self) -> bool {
        // need room for a full verify window on the target and a token on
        // the draft side
        self.seq.len() + self.cfg.max_batch_drafts + 2 < self.cloud.target.max_len()
            && self.seq.len() + self.cfg.max_batch_drafts + 2 < self.edge_max_len()
    }

    fn edge_max_len(&self) -> usize {
        self.edge.draft.max_len()
    }
}

/// Cloud-only autoregressive baseline over the same latency model: the
/// prompt goes up once, every generated token comes back down.
pub struct ArBaseline<T: TargetLm> {
    pub cloud: CloudNode<T>,
    pub link: SimulatedLink,
    pub temp: f32,
    pub timing: TimingMode,
}

impl<T: TargetLm> ArBaseline<T> {
    pub fn new(target: T, link: SimulatedLink, temp: f32, seed: u64,
               timing: TimingMode) -> Self {
        ArBaseline {
            cloud: CloudNode::new(target, seed ^ 0xA2),
            link,
            temp,
            timing,
        }
    }

    pub fn run(&mut self, prompt: &[u16], max_new_tokens: usize) -> Result<SessionResult> {
        self.cloud.start(prompt)?;
        let mut seq = prompt.to_vec();
        // prompt uplink: raw bytes (8 bits/token) once
        let mut t_up = self.link.send_uplink(prompt.len() * 8);
        let mut t_llm = 0.0;
        let mut t_down = 0.0;
        let mut downlink_bits = 0u64;
        while seq.len() - prompt.len() < max_new_tokens
            && seq.len() + 2 < self.cloud.target.max_len()
        {
            let (tok, t) = self.cloud.decode_one(self.temp)?;
            t_llm += match self.timing {
                TimingMode::Measured => t,
                TimingMode::Modeled { llm_call_s, .. } => llm_call_s,
            };
            t_down += self.link.send_downlink(8);
            downlink_bits += 8;
            seq.push(tok);
        }
        Ok(SessionResult {
            prompt_len: prompt.len(),
            tokens: seq,
            batches: Vec::new(),
            n_rej: 0,
            total_time_s: t_up + t_llm + t_down,
            t_slm_s: 0.0,
            t_uplink_s: t_up,
            t_llm_s: t_llm,
            t_downlink_s: t_down,
            uplink_bits: (prompt.len() * 8) as u64,
            downlink_bits,
            handshake_uplink_bits: 0,
            handshake_downlink_bits: 0,
            conformal_empirical_alpha: None,
            conformal_bound: None,
            conformal_t: None,
        })
    }
}
