//! Multi-request scheduler: FIFO request queue + a pool of worker threads.
//!
//! PJRT's client type is thread-bound (Rc internally), so workers cannot
//! share compiled executables; instead each worker thread constructs its
//! own backend via the supplied factory — for the PJRT path that means one
//! engine + model set per worker (weights uploaded per worker), mirroring
//! a multi-replica serving deployment; for the synthetic path it is free.
//!
//! Invariants (tested): every submitted request is answered exactly once,
//! results carry their request ids, and a failing request does not take
//! the worker down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::session::SessionResult;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub worker: usize,
    pub result: Result<SessionResult>,
}

/// A worker is a closure that serves one request; built per-thread by the
/// factory so non-Send backends (PJRT) work.
pub type Worker = Box<dyn FnMut(&Request) -> Result<SessionResult>>;
pub type WorkerFactory = Arc<dyn Fn(usize) -> Result<Worker> + Send + Sync>;

pub struct Scheduler {
    tx: Sender<Request>,
    rx_resp: Receiver<Response>,
    handles: Vec<JoinHandle<()>>,
    submitted: AtomicUsize,
}

impl Scheduler {
    /// Spawn `n_workers` threads, each constructing its backend via
    /// `factory(worker_id)`.
    pub fn start(n_workers: usize, factory: WorkerFactory) -> Result<Scheduler> {
        assert!(n_workers >= 1);
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let (tx_resp, rx_resp) = channel::<Response>();
        let mut handles = Vec::new();
        // Init-failure contract: a worker whose factory() fails exits, but
        // the *last* worker to fail (when every worker failed) stays behind
        // and answers each request with an error Response — otherwise
        // submitted requests are never answered and finish() under-returns.
        let alive = Arc::new(AtomicUsize::new(n_workers));
        for w in 0..n_workers {
            let rx = Arc::clone(&rx);
            let tx_resp = tx_resp.clone();
            let factory = Arc::clone(&factory);
            let alive = Arc::clone(&alive);
            handles.push(std::thread::Builder::new()
                .name(format!("sqs-worker-{w}"))
                .spawn(move || {
                    let mut worker = match factory(w) {
                        Ok(wk) => wk,
                        Err(e) => {
                            crate::warn!("worker {w} failed to init: {e}");
                            if alive.fetch_sub(1, Ordering::SeqCst) != 1 {
                                return; // other workers cover the queue
                            }
                            // no worker survived: stay in the loop as an
                            // error-returning worker so every request is
                            // still answered exactly once
                            let msg = format!(
                                "all workers failed to initialize; \
                                 worker {w}'s error: {e:#}"
                            );
                            Box::new(move |_req: &Request| {
                                Err(anyhow::anyhow!("{msg}"))
                            }) as Worker
                        }
                    };
                    loop {
                        let req = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        let req = match req {
                            Ok(r) => r,
                            Err(_) => break, // queue closed
                        };
                        let result = worker(&req);
                        if tx_resp
                            .send(Response { id: req.id, worker: w, result })
                            .is_err()
                        {
                            break;
                        }
                    }
                })?);
        }
        Ok(Scheduler { tx, rx_resp, handles, submitted: AtomicUsize::new(0) })
    }

    pub fn submit(&self, req: Request) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.tx.send(req).expect("scheduler queue closed");
    }

    /// Drain all responses for the submitted requests, then join workers.
    pub fn finish(self) -> Vec<Response> {
        let n = self.submitted.load(Ordering::SeqCst);
        drop(self.tx); // close the queue so workers exit after draining
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.rx_resp.recv() {
                Ok(r) => out.push(r),
                Err(_) => break, // all workers died
            }
        }
        for h in self.handles {
            let _ = h.join();
        }
        out.sort_by_key(|r| r.id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{LinkConfig, SimulatedLink};
    use crate::coordinator::session::{SdSession, SessionConfig, TimingMode};
    use crate::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
    use crate::sqs::Policy;

    fn synthetic_factory(policy: Policy) -> WorkerFactory {
        Arc::new(move |worker_id| {
            let world = SyntheticWorld::new(64, 0.5, 11);
            let cfg = SessionConfig {
                policy,
                temp: 0.9,
                max_new_tokens: 16,
                seed: worker_id as u64,
                timing: TimingMode::Modeled { slm_step_s: 1e-4, llm_call_s: 1e-3 },
                ..Default::default()
            };
            Ok(Box::new(move |req: &Request| {
                let draft = SyntheticDraft::new(world.clone(), 100_000);
                let target = SyntheticTarget::new(world.clone(), 15, 100_000);
                let link = SimulatedLink::new(LinkConfig::default(), req.id);
                let mut cfg = cfg.clone();
                cfg.max_new_tokens = req.max_new_tokens;
                cfg.seed ^= req.id;
                let mut sess = SdSession::new(draft, target, link, cfg);
                sess.run(&req.prompt)
            }) as Worker)
        })
    }

    #[test]
    fn all_requests_answered_exactly_once() {
        let sched = Scheduler::start(4, synthetic_factory(Policy::KSqs { k: 8 })).unwrap();
        for id in 0..20 {
            sched.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 8 });
        }
        let responses = sched.finish();
        assert_eq!(responses.len(), 20);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..20).collect::<Vec<u64>>());
        for r in &responses {
            let res = r.result.as_ref().unwrap();
            // a batch commits accepted drafts + 1 cloud token, so the
            // session may overshoot the cap by exactly the bonus token
            assert!(
                (8..=9).contains(&res.new_tokens()),
                "new_tokens = {}", res.new_tokens()
            );
        }
    }

    #[test]
    fn work_is_distributed_across_workers() {
        let sched = Scheduler::start(3, synthetic_factory(Policy::KSqs { k: 4 })).unwrap();
        for id in 0..30 {
            sched.submit(Request { id, prompt: vec![7], max_new_tokens: 4 });
        }
        let responses = sched.finish();
        let mut used = std::collections::HashSet::new();
        for r in &responses {
            used.insert(r.worker);
        }
        assert!(used.len() >= 2, "expected >= 2 workers used, got {used:?}");
    }

    #[test]
    fn all_workers_failing_init_surface_error_responses() {
        let factory: WorkerFactory =
            Arc::new(|w| Err(anyhow::anyhow!("no backend for worker {w}")));
        let sched = Scheduler::start(3, factory).unwrap();
        for id in 0..5 {
            sched.submit(Request { id, prompt: vec![1], max_new_tokens: 2 });
        }
        let responses = sched.finish();
        assert_eq!(responses.len(), 5, "every request must be answered");
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..5).collect::<Vec<u64>>());
        for r in &responses {
            let err = r.result.as_ref().unwrap_err().to_string();
            assert!(err.contains("failed to initialize"), "unexpected error: {err}");
        }
    }

    #[test]
    fn partial_init_failure_still_serves_all_requests() {
        let inner = synthetic_factory(Policy::KSqs { k: 8 });
        let factory: WorkerFactory = Arc::new(move |w| {
            if w == 0 {
                Err(anyhow::anyhow!("worker 0 has no accelerator"))
            } else {
                inner(w)
            }
        });
        let sched = Scheduler::start(2, factory).unwrap();
        for id in 0..6 {
            sched.submit(Request { id, prompt: vec![2], max_new_tokens: 4 });
        }
        let responses = sched.finish();
        assert_eq!(responses.len(), 6);
        for r in &responses {
            assert!(r.result.is_ok(), "healthy worker must cover the fleet");
            assert_eq!(r.worker, 1);
        }
    }

    #[test]
    fn failing_request_does_not_kill_worker() {
        let sched = Scheduler::start(1, synthetic_factory(Policy::KSqs { k: 8 })).unwrap();
        // empty prompt -> error; next request must still be served
        sched.submit(Request { id: 0, prompt: vec![], max_new_tokens: 4 });
        sched.submit(Request { id: 1, prompt: vec![3], max_new_tokens: 4 });
        let responses = sched.finish();
        assert_eq!(responses.len(), 2);
        assert!(responses[0].result.is_err());
        assert!(responses[1].result.is_ok());
    }
}
