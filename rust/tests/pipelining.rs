//! Pipelined-session contract tests.
//!
//! (1) Regression: `pipeline_depth = 1` must be BIT-IDENTICAL to the
//!     frozen protocol-v2 alternating loop — over the session engine
//!     (vs `run_reference_lockstep`), the fleet simulator (explicit
//!     depth 1 vs default profile), and the TCP wire path.
//! (2) Pipelined runs stay a pure function of (config, seed).
//! (3) On a high-RTT link, depth >= 2 reduces end-to-end latency by
//!     overlapping draft compute with the verification round trip.
//! (4) Stale/duplicate-feedback and discard accounting invariants hold.

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::control::AdaptiveMode;
use sqs_sd::coordinator::session::{SdSession, SessionConfig, SessionResult, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetSim, VerifierConfig, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;

fn modeled() -> TimingMode {
    TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 }
}

fn make_session(
    world: &SyntheticWorld,
    link: LinkConfig,
    schedule: Vec<(u64, f64)>,
    cfg: SessionConfig,
) -> SdSession<SyntheticDraft, SyntheticTarget> {
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), cfg.max_batch_drafts, 1_000_000);
    let link = SimulatedLink::new(link, cfg.seed).with_uplink_schedule(schedule);
    SdSession::new(draft, target, link, cfg)
}

/// Field-by-field bit identity of two session results (floats via
/// to_bits, so "close" is not good enough).
fn assert_bit_identical(a: &SessionResult, b: &SessionResult, what: &str) {
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.prompt_len, b.prompt_len, "{what}: prompt_len");
    assert_eq!(a.n_rej, b.n_rej, "{what}: n_rej");
    assert_eq!(a.tree_branching, b.tree_branching, "{what}: tree_branching");
    assert_eq!(a.discarded_batches, b.discarded_batches, "{what}: discarded");
    assert_eq!(a.uplink_bits, b.uplink_bits, "{what}: uplink_bits");
    assert_eq!(a.downlink_bits, b.downlink_bits, "{what}: downlink_bits");
    assert_eq!(a.handshake_uplink_bits, b.handshake_uplink_bits, "{what}: hs up");
    assert_eq!(a.handshake_downlink_bits, b.handshake_downlink_bits, "{what}: hs down");
    assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits(), "{what}: total");
    assert_eq!(a.t_slm_s.to_bits(), b.t_slm_s.to_bits(), "{what}: t_slm");
    assert_eq!(a.t_uplink_s.to_bits(), b.t_uplink_s.to_bits(), "{what}: t_uplink");
    assert_eq!(a.t_llm_s.to_bits(), b.t_llm_s.to_bits(), "{what}: t_llm");
    assert_eq!(a.t_downlink_s.to_bits(), b.t_downlink_s.to_bits(), "{what}: t_downlink");
    assert_eq!(a.batches.len(), b.batches.len(), "{what}: batch count");
    for (i, (x, y)) in a.batches.iter().zip(&b.batches).enumerate() {
        assert_eq!(x.drafted, y.drafted, "{what}: batch {i} drafted");
        assert_eq!(x.accepted, y.accepted, "{what}: batch {i} accepted");
        assert_eq!(x.rejected, y.rejected, "{what}: batch {i} rejected");
        assert_eq!(x.dist_bits, y.dist_bits, "{what}: batch {i} dist_bits");
        assert_eq!(x.tree_nodes, y.tree_nodes, "{what}: batch {i} tree_nodes");
        assert_eq!(x.frame_bits, y.frame_bits, "{what}: batch {i} frame_bits");
        assert_eq!(x.feedback_bits, y.feedback_bits, "{what}: batch {i} feedback_bits");
        assert_eq!(x.knobs, y.knobs, "{what}: batch {i} knobs");
        assert_eq!(x.mean_k.to_bits(), y.mean_k.to_bits(), "{what}: batch {i} mean_k");
        assert_eq!(x.t_slm.to_bits(), y.t_slm.to_bits(), "{what}: batch {i} t_slm");
        assert_eq!(x.t_uplink.to_bits(), y.t_uplink.to_bits(), "{what}: batch {i} t_uplink");
        assert_eq!(x.t_llm.to_bits(), y.t_llm.to_bits(), "{what}: batch {i} t_llm");
        assert_eq!(x.t_downlink.to_bits(), y.t_downlink.to_bits(), "{what}: batch {i} t_down");
    }
}

/// THE regression the refactor hangs on: the in-flight ledger engine at
/// depth 1 reproduces the frozen v2 alternating loop bit for bit —
/// every policy, every adaptive mode, jittered links, mid-run bandwidth
/// schedules.
#[test]
fn depth_one_engine_is_bit_identical_to_the_v2_reference() {
    let world = SyntheticWorld::new(64, 0.6, 7);
    let cases: Vec<(Policy, AdaptiveMode)> = vec![
        (Policy::KSqs { k: 8 }, AdaptiveMode::Off),
        (Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 }, AdaptiveMode::Off),
        (Policy::DenseQs, AdaptiveMode::Off),
        (Policy::KSqs { k: 8 }, AdaptiveMode::Aimd { target_bits: 600 }),
        (Policy::KSqs { k: 8 }, AdaptiveMode::Window { grow: 0.8, shrink: 0.5 }),
    ];
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.010,
        jitter_s: 0.002, // exercise the seeded jitter RNG path too
    };
    for (policy, adaptive) in cases {
        let cfg = SessionConfig {
            policy,
            temp: 0.9,
            max_new_tokens: 48,
            seed: 11,
            timing: modeled(),
            adaptive,
            pipeline_depth: 1,
            ..Default::default()
        };
        let schedule = vec![(10, 2.5e5)]; // mid-run bandwidth drop
        let a = make_session(&world, link, schedule.clone(), cfg.clone())
            .run(&[3, 1, 4])
            .unwrap();
        let b = make_session(&world, link, schedule, cfg)
            .run_reference_lockstep(&[3, 1, 4])
            .unwrap();
        assert_eq!(a.pipeline_depth, 1);
        assert_eq!(a.discarded_batches, 0, "depth 1 never discards");
        assert_bit_identical(&a, &b, &format!("{policy:?}/{adaptive:?}"));
    }
}

/// Pipelined sessions are a pure function of (config, seed).
#[test]
fn pipelined_session_is_deterministic() {
    let world = SyntheticWorld::new(64, 0.4, 21);
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.050,
        jitter_s: 0.001,
    };
    let run = |seed: u64| {
        let cfg = SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.8,
            max_new_tokens: 64,
            max_batch_drafts: 4,
            seed,
            timing: modeled(),
            pipeline_depth: 3,
            ..Default::default()
        };
        make_session(&world, link, Vec::new(), cfg).run(&[9, 2]).unwrap()
    };
    let (a, b) = (run(5), run(5));
    assert_bit_identical(&a, &b, "same seed");
    let c = run(6);
    assert_ne!(a.tokens, c.tokens, "seeds must matter");
}

/// The acceptance-criterion shape: on a high-RTT link, pipelining hides
/// the verification round trip behind drafting, so depth >= 2 finishes
/// the same request in less virtual time than the alternating protocol.
/// Small windows keep full acceptance common, which is what makes the
/// speculation survive.
#[test]
fn pipelining_reduces_latency_on_a_high_rtt_link() {
    let world = SyntheticWorld::new(64, 0.3, 2024);
    // 100 ms RTT: propagation dominates every round of the alternating
    // protocol; drafting a 4-token window costs only ~5 ms
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.050,
        jitter_s: 0.0,
    };
    let run = |depth: usize| {
        let cfg = SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.7,
            max_new_tokens: 64,
            max_batch_drafts: 4,
            seed: 3,
            timing: modeled(),
            pipeline_depth: depth,
            ..Default::default()
        };
        make_session(&world, link, Vec::new(), cfg).run(&[7, 21]).unwrap()
    };
    let d1 = run(1);
    let d2 = run(2);
    let d4 = run(4);
    assert!(d1.new_tokens() >= 64 && d2.new_tokens() >= 64 && d4.new_tokens() >= 64);
    assert!(
        d2.total_time_s < d1.total_time_s,
        "depth 2 must beat alternating on a high-RTT link: {} !< {}",
        d2.total_time_s,
        d1.total_time_s
    );
    assert!(
        d4.total_time_s < 0.9 * d1.total_time_s,
        "depth 4 must hide most of the round trip: {} !< 0.9 * {}",
        d4.total_time_s,
        d1.total_time_s
    );
    // overlap means the makespan undercuts the serialized component sum
    let serial = d4.t_slm_s + d4.t_uplink_s + d4.t_llm_s + d4.t_downlink_s;
    assert!(
        d4.total_time_s < serial,
        "pipelined makespan {} should undercut the component sum {serial}",
        d4.total_time_s
    );
    // every speculative batch is accounted: verified or discarded, and
    // its wire bits are in the ledger either way
    let batch_up: u64 = d4.batches.iter().map(|b| b.frame_bits as u64).sum();
    assert!(
        d4.uplink_bits >= d4.handshake_uplink_bits + batch_up,
        "discarded batches' bits stay in the uplink ledger"
    );
}

// ---------------------------------------------------------------------
// fleet paths
// ---------------------------------------------------------------------

fn fleet_cfg(depth: Option<usize>, seed: u64, propagation_s: f64) -> FleetConfig {
    let mut base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        temp: 0.7,
        max_new_tokens: 24,
        max_batch_drafts: 4,
        workload: Workload::ClosedLoop { think_s: 0.0 },
        ..Default::default()
    };
    if let Some(d) = depth {
        base.pipeline_depth = d;
    }
    let mut cfg = FleetConfig::uniform(3, base);
    cfg.uplink_bps = 1e6;
    cfg.propagation_s = propagation_s;
    cfg.requests_per_device = 3;
    // a gentle draft-target mismatch keeps full acceptance common, so
    // small windows of speculation mostly survive
    cfg.mismatch = 0.3;
    cfg.verifier = VerifierConfig { concurrency: 3, batch_max: 2, ..Default::default() };
    cfg.seed = seed;
    cfg.record_trace = true;
    cfg
}

/// Fleet regression: an explicit `pipeline_depth: 1` profile must take
/// exactly the pre-pipelining event path — same trace, same digest — as
/// the default profile.
#[test]
fn fleet_depth_one_is_bit_identical_to_default() {
    let a = FleetSim::new(fleet_cfg(Some(1), 909, 0.010)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(None, 909, 0.010)).run().unwrap();
    assert!(!a.trace.is_empty());
    assert_eq!(a.trace, b.trace, "event traces diverge");
    assert_eq!(a.digest(), b.digest(), "metrics digests diverge");
    assert_eq!(a.discarded_batches, 0);
}

/// Pipelined fleets stay bit-reproducible and beat alternating fleets
/// on a high-RTT shared link (uncontended verifier, roomy uplink: the
/// round trip is the bottleneck pipelining removes).
#[test]
fn pipelined_fleet_is_deterministic_and_faster_on_high_rtt() {
    let a = FleetSim::new(fleet_cfg(Some(3), 42, 0.050)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(Some(3), 42, 0.050)).run().unwrap();
    assert_eq!(a.trace, b.trace, "pipelined event traces diverge");
    assert_eq!(a.digest(), b.digest());

    let c = FleetSim::new(fleet_cfg(Some(3), 43, 0.050)).run().unwrap();
    assert_ne!(a.trace, c.trace, "seeds must matter");

    let alternating = FleetSim::new(fleet_cfg(Some(1), 42, 0.050)).run().unwrap();
    assert_eq!(a.completed, alternating.completed, "same workload either way");
    assert!(
        a.latency.mean() < alternating.latency.mean(),
        "pipelined fleet must cut mean latency on a 100ms-RTT link: {} !< {}",
        a.latency.mean(),
        alternating.latency.mean()
    );
}

/// Adaptive grants converge: a congested AIMD fleet under a fair-share
/// grant pool settles near pool/N bits per round, and the grants relax
/// as sessions drain (ROADMAP "adaptive grants" acceptance test).
#[test]
fn adaptive_grant_pool_converges_to_fair_share() {
    let n = 6usize;
    let pool = 3600u32; // fair share: 600 bits/round per live session
    let mk = |congestion_depth: usize, pool_bits: Option<u32>| {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            max_new_tokens: 32,
            adaptive: AdaptiveMode::Aimd { target_bits: 5000 },
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(n, base);
        cfg.uplink_bps = 1e6;
        cfg.requests_per_device = 3;
        cfg.seed = 77;
        cfg.verifier = VerifierConfig {
            concurrency: 2,
            batch_max: 4,
            congestion_depth,
            grant_pool_bits: pool_bits,
            grant_min_bits: 64,
            ..Default::default()
        };
        cfg
    };
    // free: no congestion signal at all; pooled: grant on every frame
    let free = FleetSim::new(mk(usize::MAX, None)).run().unwrap();
    let pooled = FleetSim::new(mk(0, Some(pool))).run().unwrap();

    let share = pool as f64 / n as f64;
    let free_bpr = free.mean_bits_per_round();
    let pooled_bpr = pooled.mean_bits_per_round();
    assert!(
        free_bpr > share * 2.0,
        "without the pool, AIMD settles far above the fair share ({free_bpr:.0})"
    );
    assert!(
        pooled_bpr < free_bpr,
        "the grant pool must throttle the fleet ({pooled_bpr:.0} vs {free_bpr:.0})"
    );
    // convergence to the *neighborhood* of the fair share: grants move
    // with load (scaled down by backlog pressure, up as sessions drain),
    // so the mean sits near pool/N rather than exactly on it
    assert!(
        pooled_bpr <= share * 2.0 && pooled_bpr >= share * 0.2,
        "fleet converges near the {share:.0}b fair share, got {pooled_bpr:.0}"
    );
    // every granted budget is a live fair share, never the configured
    // 5000b target again (round 0 predates any feedback), bounded by
    // the whole pool (live >= 1) and floored at grant_min_bits
    for d in &pooled.per_device {
        assert!(d.knob_trace.len() >= 2, "device {} ran {} rounds", d.id, d.knob_trace.len());
        assert_eq!(d.knob_trace[0].budget_bits, 5000, "round 0 predates any grant");
        for kp in &d.knob_trace[1..] {
            assert!(
                kp.budget_bits >= 64 && kp.budget_bits <= pool as usize,
                "device {}: granted budget {} outside [64, {pool}]",
                d.id,
                kp.budget_bits
            );
        }
    }
    // the un-pooled fleet never sees a grant: configured target only
    for d in &free.per_device {
        for kp in &d.knob_trace {
            assert_eq!(kp.budget_bits, 5000, "no pool: configured target everywhere");
        }
    }

    // pure function of (config, seed)
    let again = FleetSim::new(mk(0, Some(pool))).run().unwrap();
    assert_eq!(pooled.digest(), again.digest());
}
