//! Token-tree speculation (protocol v4) contract tests.
//!
//! (1) Regression: `tree_branching = 1` must be BIT-IDENTICAL to the v3
//!     linear pipeline at the same depth — over the session engine, the
//!     fleet simulator (explicit branching-1 profile vs default), and
//!     the TCP wire path — exactly the way depth 1 is pinned to v2.
//! (2) Tree sessions stay a pure function of (config, seed).
//! (3) THE tentpole claim: in a high-rejection regime at equal depth,
//!     tree speculation strictly reduces discarded batches vs. the
//!     linear pipeline — surviving into a rejection continuation
//!     commits more tokens per round, so the request takes fewer
//!     rounds and fewer epoch bumps kill fewer in-flight frames.
//! (4) Exactness: the multi-candidate residual walk still emits tokens
//!     from the target distribution.
//! (5) Stale-epoch trees are discarded (uplink in, discard ack out) on
//!     the session, fleet, and TCP FIFO paths.

use std::net::TcpStream;

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::codec::{DraftFrame, DraftToken};
use sqs_sd::coordinator::session::{SdSession, SessionConfig, SessionResult, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetSim, VerifierConfig, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::protocol::{
    Control, Direction, Frame, StreamTransport, Transport, TreeDraft, WireCodec, NO_PARENT,
    PROTOCOL_V4,
};
use sqs_sd::server::wire::{WireEdge, WireEdgeConfig, WireServer, WireServerConfig};
use sqs_sd::sqs::bits::SchemeBits;
use sqs_sd::sqs::{sparse_quantize, Policy, Sparsifier};
use sqs_sd::util::stats::tv_distance;

fn modeled() -> TimingMode {
    TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 }
}

fn make_session(
    world: &SyntheticWorld,
    link: LinkConfig,
    cfg: SessionConfig,
) -> SdSession<SyntheticDraft, SyntheticTarget> {
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), cfg.max_batch_drafts, 1_000_000);
    let link = SimulatedLink::new(link, cfg.seed);
    SdSession::new(draft, target, link, cfg)
}

fn wan() -> LinkConfig {
    LinkConfig { uplink_bps: 1e6, downlink_bps: 1e7, propagation_s: 0.050, jitter_s: 0.0 }
}

fn session_cfg(depth: usize, branching: usize, seed: u64, max_new: usize) -> SessionConfig {
    SessionConfig {
        policy: Policy::KSqs { k: 8 },
        temp: 0.8,
        max_new_tokens: max_new,
        max_batch_drafts: 4,
        seed,
        timing: modeled(),
        pipeline_depth: depth,
        tree_branching: branching,
        ..Default::default()
    }
}

/// Field-by-field bit identity (floats via to_bits), minus the
/// `tree_branching` echo itself — the configs intentionally differ on
/// that knob while every observable must agree.
fn assert_same_run(a: &SessionResult, b: &SessionResult, what: &str) {
    assert_eq!(a.tokens, b.tokens, "{what}: tokens");
    assert_eq!(a.n_rej, b.n_rej, "{what}: n_rej");
    assert_eq!(a.discarded_batches, b.discarded_batches, "{what}: discarded");
    assert_eq!(a.uplink_bits, b.uplink_bits, "{what}: uplink_bits");
    assert_eq!(a.downlink_bits, b.downlink_bits, "{what}: downlink_bits");
    assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits(), "{what}: total");
    assert_eq!(a.batches.len(), b.batches.len(), "{what}: batch count");
    for (i, (x, y)) in a.batches.iter().zip(&b.batches).enumerate() {
        assert_eq!(x.drafted, y.drafted, "{what}: batch {i} drafted");
        assert_eq!(x.accepted, y.accepted, "{what}: batch {i} accepted");
        assert_eq!(x.tree_nodes, y.tree_nodes, "{what}: batch {i} nodes");
        assert_eq!(x.frame_bits, y.frame_bits, "{what}: batch {i} frame_bits");
        assert_eq!(x.feedback_bits, y.feedback_bits, "{what}: batch {i} fb bits");
    }
}

/// (1a) Session path: a `tree_branching: 1` session at depth >= 2 takes
/// exactly the v3 linear pipeline — same frames, same bits, same times
/// — as a session that never heard of the knob.
#[test]
fn branching_one_session_is_bit_identical_to_the_v3_pipeline() {
    let world = SyntheticWorld::new(64, 0.6, 7);
    for depth in [2usize, 3] {
        let explicit = make_session(&world, wan(), session_cfg(depth, 1, 11, 48))
            .run(&[3, 1, 4])
            .unwrap();
        let default_cfg = SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.8,
            max_new_tokens: 48,
            max_batch_drafts: 4,
            seed: 11,
            timing: modeled(),
            pipeline_depth: depth,
            ..Default::default() // no tree_branching mention at all
        };
        let plain = make_session(&world, wan(), default_cfg).run(&[3, 1, 4]).unwrap();
        assert_same_run(&explicit, &plain, &format!("depth {depth}"));
        // linear pipelines never carry extra wire nodes
        for b in &explicit.batches {
            assert_eq!(b.tree_nodes, b.drafted, "branching 1 ships linear frames");
        }
    }
}

/// (2) Tree sessions are a pure function of (config, seed), and the
/// seed matters.
#[test]
fn tree_session_is_deterministic() {
    let world = SyntheticWorld::new(64, 0.6, 21);
    let run = |seed: u64| {
        make_session(&world, wan(), session_cfg(3, 3, seed, 64)).run(&[9, 2]).unwrap()
    };
    let (a, b) = (run(5), run(5));
    assert_same_run(&a, &b, "same seed");
    assert_eq!(a.tree_branching, 3);
    let c = run(6);
    assert_ne!(a.tokens, c.tokens, "seeds must matter");
    // the tree actually went on the wire: some verified round carried
    // more nodes than its trunk
    assert!(
        a.batches.iter().any(|r| r.tree_nodes > r.drafted),
        "no tree frame was ever shipped"
    );
    assert!(a.new_tokens() >= 64, "request completed: {} tokens", a.new_tokens());
}

/// (3) THE acceptance criterion: in a high-rejection regime, trees
/// strictly reduce discarded batches vs. linear at equal depth.  A
/// rejection that survives into a sibling chain commits up to a full
/// window instead of `accepted + 1` tokens, so the same request takes
/// fewer rounds — and each epoch bump therefore kills fewer frames.
/// Summed over seeds so one lucky trajectory cannot mask the effect.
#[test]
fn trees_strictly_reduce_discards_under_high_rejection() {
    let world = SyntheticWorld::new(64, 1.0, 404); // heavy draft-target mismatch
    let total = |branching: usize| -> (u64, u64, usize) {
        let mut discards = 0u64;
        let mut batches = 0u64;
        let mut tokens = 0usize;
        for seed in 0..6u64 {
            let r = make_session(&world, wan(), session_cfg(3, branching, 100 + seed, 96))
                .run(&[5, 9])
                .unwrap();
            assert!(r.new_tokens() >= 96, "branching {branching}: request completed");
            discards += r.discarded_batches as u64;
            batches += r.batches.len() as u64;
            tokens += r.new_tokens();
        }
        (discards, batches, tokens)
    };
    let (lin_disc, lin_batches, lin_tokens) = total(1);
    let (tree_disc, tree_batches, tree_tokens) = total(3);
    assert!(lin_disc > 0, "scenario must actually discard (got {lin_disc})");
    assert!(
        tree_disc < lin_disc,
        "tree speculation must strictly reduce discards: {tree_disc} !< {lin_disc}"
    );
    // the mechanism: more tokens per verified round => fewer rounds
    let lin_tpb = lin_tokens as f64 / lin_batches as f64;
    let tree_tpb = tree_tokens as f64 / tree_batches as f64;
    assert!(
        tree_tpb > lin_tpb,
        "trees must commit more per round: {tree_tpb:.3} !> {lin_tpb:.3}"
    );
}

/// (4) Exactness: the multi-candidate residual walk still emits the
/// target distribution.  The synthetic world is Markov, so the first
/// generated token after prompt [s] across many seeded tree sessions
/// must be distributed as p(. | s).
#[test]
fn tree_outputs_follow_target_distribution() {
    let world = SyntheticWorld::new(32, 0.8, 99);
    let temp = 0.9f32;
    let prev = 5u16;
    let p_ref = world.target_probs(prev, temp);

    let n = 20_000usize;
    let mut freq = vec![0u64; 32];
    for seed in 0..n {
        let cfg = SessionConfig {
            policy: Policy::KSqs { k: 4 },
            temp,
            max_new_tokens: 1,
            max_batch_drafts: 4,
            seed: seed as u64,
            timing: modeled(),
            pipeline_depth: 2,
            tree_branching: 3,
            ..Default::default()
        };
        let res = make_session(&world, LinkConfig::default(), cfg).run(&[prev]).unwrap();
        freq[res.tokens[1] as usize] += 1;
    }
    let emp: Vec<f32> = freq.iter().map(|&c| c as f32 / n as f32).collect();
    let tv = tv_distance(&emp, &p_ref);
    // TV of an n-sample empirical distribution over 32 outcomes
    // concentrates near sqrt(V/(2*pi*n)) ~ 0.016; 0.035 is ~3 sigma.
    assert!(tv < 0.035, "tree walk broke the SD guarantee: TV {tv:.4}");
}

// ---------------------------------------------------------------------
// fleet paths
// ---------------------------------------------------------------------

fn fleet_cfg(branching: Option<usize>, seed: u64) -> FleetConfig {
    let mut base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        temp: 0.8,
        max_new_tokens: 24,
        max_batch_drafts: 4,
        workload: Workload::ClosedLoop { think_s: 0.0 },
        pipeline_depth: 3,
        ..Default::default()
    };
    if let Some(b) = branching {
        base.tree_branching = b;
    }
    let mut cfg = FleetConfig::uniform(3, base);
    cfg.uplink_bps = 1e6;
    cfg.propagation_s = 0.050;
    cfg.requests_per_device = 3;
    cfg.mismatch = 0.8;
    cfg.verifier = VerifierConfig { concurrency: 3, batch_max: 2, ..Default::default() };
    cfg.seed = seed;
    cfg.record_trace = true;
    cfg
}

/// (1b) Fleet path: an explicit branching-1 profile takes exactly the
/// linear-pipeline event path — same trace, same digest — as a profile
/// that never mentions the knob.
#[test]
fn fleet_branching_one_is_bit_identical_to_default() {
    let a = FleetSim::new(fleet_cfg(Some(1), 909)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(None, 909)).run().unwrap();
    assert!(!a.trace.is_empty());
    assert_eq!(a.trace, b.trace, "event traces diverge");
    assert_eq!(a.digest(), b.digest(), "metrics digests diverge");
}

/// (5, fleet direction) Tree fleets complete, stay bit-reproducible,
/// and account every stale tree the verifier discarded.
#[test]
fn tree_fleet_is_deterministic_and_accounts_discards() {
    let a = FleetSim::new(fleet_cfg(Some(2), 42)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(Some(2), 42)).run().unwrap();
    assert_eq!(a.trace, b.trace, "tree event traces diverge");
    assert_eq!(a.digest(), b.digest());
    assert_eq!(a.completed, 9, "3 devices x 3 requests");
    for d in &a.per_device {
        assert_eq!(
            d.knob_trace.len() as u64,
            d.batches + d.discarded_batches,
            "device {}: every drafted tree is acked exactly once",
            d.id
        );
    }
    let c = FleetSim::new(fleet_cfg(Some(2), 43)).run().unwrap();
    assert_ne!(a.trace, c.trace, "seeds must matter");
}

// ---------------------------------------------------------------------
// TCP wire path
// ---------------------------------------------------------------------

fn run_tcp(seed: u64, depth: usize, branching: usize) -> sqs_sd::server::wire::WireRunReport {
    let cfg = WireServerConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: Some(1),
        congestion_depth: usize::MAX,
        seed,
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let world = server.world().clone();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut transport = StreamTransport::new(stream);
    let draft = SyntheticDraft::new(world, 100_000);
    let edge_cfg = WireEdgeConfig {
        policy: Policy::KSqs { k: 8 },
        max_batch_drafts: 4,
        pipeline_depth: depth,
        tree_branching: branching,
        seed,
        ..Default::default()
    };
    let mut edge = WireEdge::new(draft, edge_cfg);
    let report = edge.run(&mut transport, &[3, 1, 4], 32).unwrap();
    handle.join().unwrap();
    report
}

/// (1c) TCP path: a branching-1 client is bit-identical to a linear
/// pipelined client — tokens, per-frame sizes, stream ledgers.
#[test]
fn tcp_branching_one_is_bit_identical_to_the_linear_client() {
    let a = run_tcp(17, 3, 1);
    let b = run_tcp(17, 3, 0); // 0 is clamped to 1: the knob's off state
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.frame_bits, b.frame_bits);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.downlink_bits, b.downlink_bits);
}

/// Tree sessions over a real socket: negotiation lands on v4, the
/// session completes, and reruns are bit-identical from (config, seed).
#[test]
fn tcp_tree_session_round_trips_and_is_deterministic() {
    let r = run_tcp(42, 3, 2);
    assert!(r.new_tokens() >= 32, "request completed: {} tokens", r.new_tokens());
    assert!(r.batches > 0);
    // trees multiply wire cost: the tree client ships more uplink bits
    // than the linear client for the same request shape
    let lin = run_tcp(42, 3, 1);
    assert!(
        r.uplink_bits > lin.uplink_bits,
        "tree frames must cost more uplink bits ({} !> {})",
        r.uplink_bits,
        lin.uplink_bits
    );
    let r2 = run_tcp(42, 3, 2);
    assert_eq!(r.tokens, r2.tokens);
    assert_eq!(r.uplink_bits, r2.uplink_bits);
    assert_eq!(r.downlink_bits, r2.downlink_bits);
    assert_eq!(r.discarded, r2.discarded);
    let r3 = run_tcp(43, 3, 2);
    assert_ne!(r.tokens, r3.tokens, "seeds must matter");
}

/// (5, TCP direction) A stale-epoch tree is discarded by the server
/// and the discard ack retires the seq at the client: uplink in,
/// linear discard ack out — both FIFO directions exercised with a
/// hand-rolled v4 client.
#[test]
fn tcp_stale_epoch_tree_is_discarded() {
    let cfg = WireServerConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: Some(1),
        congestion_depth: usize::MAX,
        seed: 3,
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut tr = StreamTransport::new(stream);
    let mut wire = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    wire.set_version(PROTOCOL_V4);

    // handshake + prompt
    let hello = wire.hello().unwrap();
    tr.send_frame(Direction::Up, &Frame::Hello(hello), &mut wire, 0.0).unwrap();
    let ack = match tr.recv_frame(Direction::Down, &mut wire).unwrap() {
        Frame::HelloAck(a) => a,
        other => panic!("expected HelloAck, got {}", other.name()),
    };
    assert!(ack.ok);
    assert_eq!(ack.version, PROTOCOL_V4, "server speaks v4");
    tr.send_frame(Direction::Up, &Frame::Control(Control::Prompt(vec![1, 2])), &mut wire, 0.0)
        .unwrap();

    // a syntactically valid tree stamped with a future epoch: the
    // server's cloud epoch is 0, so this must come back as a discard
    let mut g = sqs_sd::util::check::Gen { rng: sqs_sd::util::rng::Pcg64::new(8, 8) };
    let tokens: Vec<DraftToken> = (0..2)
        .map(|_| {
            let q = g.probs(64, 2.0);
            let quant = sparse_quantize(&q, &Sparsifier::top_k(8), 100);
            let token = quant.support[0];
            DraftToken { quant, token }
        })
        .collect();
    let td = TreeDraft {
        seq: 7,
        epoch: 1, // stale: server is at epoch 0
        parents: vec![NO_PARENT, 0],
        frame: DraftFrame { batch_id: 1, tokens },
    };
    tr.send_frame(Direction::Up, &Frame::DraftTree(td), &mut wire, 0.0).unwrap();
    let fb = match tr.recv_frame(Direction::Down, &mut wire).unwrap() {
        Frame::Feedback(f) => f,
        other => panic!("expected Feedback, got {}", other.name()),
    };
    assert_eq!(fb.acked_seq(), Some((7, true)), "stale tree must be discard-acked");
    assert_eq!(fb.accepted, 0);
    let _ = tr.send_frame(Direction::Up, &Frame::Control(Control::Bye), &mut wire, 0.0);
    handle.join().unwrap();
}
