//! Fleet-simulator contract tests: (1) bit-identical reproducibility —
//! same seed + config must give the same event trace and the same metrics
//! digest across independent runs; (2) contention sanity — tightening the
//! shared uplink must not make the fleet faster.

use sqs_sd::control::AdaptiveMode;
use sqs_sd::fleet::{
    mixed_policy_profiles, DeviceProfile, FleetConfig, FleetSim, VerifierConfig, Workload,
};
use sqs_sd::sqs::Policy;

fn fleet_cfg(seed: u64, uplink_bps: f64, record_trace: bool) -> FleetConfig {
    let base = DeviceProfile {
        policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
        max_new_tokens: 16,
        workload: Workload::Poisson { rate_hz: 3.0 },
        ..Default::default()
    };
    let mut cfg = FleetConfig::with_profiles(mixed_policy_profiles(9, base));
    cfg.uplink_bps = uplink_bps;
    cfg.jitter_s = 0.002; // exercise the seeded jitter path too
    cfg.requests_per_device = 3;
    cfg.verifier = VerifierConfig { concurrency: 2, batch_max: 4, ..Default::default() };
    cfg.seed = seed;
    cfg.record_trace = record_trace;
    cfg
}

#[test]
fn same_seed_and_config_is_bit_identical() {
    let a = FleetSim::new(fleet_cfg(2024, 1e6, true)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(2024, 1e6, true)).run().unwrap();

    assert!(!a.trace.is_empty());
    assert_eq!(a.trace.len(), b.trace.len(), "event counts differ");
    for (i, (la, lb)) in a.trace.iter().zip(&b.trace).enumerate() {
        assert_eq!(la, lb, "traces diverge at event {i}");
    }
    assert_eq!(a.digest(), b.digest(), "metrics digests differ");

    // the digest covers floats via to_bits; spot-check raw aggregates too
    assert_eq!(a.completed, 27);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.verify_calls, b.verify_calls);
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    assert_eq!(a.latency.mean().to_bits(), b.latency.mean().to_bits());
}

#[test]
fn different_seeds_diverge() {
    let a = FleetSim::new(fleet_cfg(1, 1e6, true)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(2, 1e6, true)).run().unwrap();
    assert_ne!(a.trace, b.trace, "seeds must matter");
}

#[test]
fn halving_shared_uplink_does_not_decrease_mean_latency() {
    // Decouple the verifier (one slot per device, no coalescing) and use
    // zero jitter + open-loop arrivals so the uplink is the only coupled
    // stage: every frame's delivery can then only get later at half rate.
    let mk = |bps: f64| {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            max_new_tokens: 16,
            workload: Workload::Poisson { rate_hz: 4.0 },
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(8, base);
        cfg.uplink_bps = bps;
        cfg.jitter_s = 0.0;
        cfg.requests_per_device = 4;
        cfg.verifier = VerifierConfig { concurrency: 8, batch_max: 1, ..Default::default() };
        cfg.seed = 7;
        cfg
    };
    let full = FleetSim::new(mk(1e6)).run().unwrap();
    let half = FleetSim::new(mk(5e5)).run().unwrap();

    assert_eq!(full.completed, half.completed, "same workload either way");
    assert!(
        half.latency.mean() >= full.latency.mean() - 1e-9,
        "halving uplink capacity decreased mean latency: {} < {}",
        half.latency.mean(),
        full.latency.mean()
    );
    assert!(
        half.uplink_utilization >= full.uplink_utilization - 1e-9,
        "tighter link should be at least as utilized"
    );
    assert!(half.horizon_s >= full.horizon_s - 1e-9);
}

/// A mixed adaptive fleet: AIMD and adaptive-window devices interleaved
/// on a congested shared uplink.
fn adaptive_fleet_cfg(seed: u64, record_trace: bool) -> FleetConfig {
    let base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        max_new_tokens: 16,
        workload: Workload::Poisson { rate_hz: 3.0 },
        ..Default::default()
    };
    let mut profiles = vec![base; 6];
    for (i, p) in profiles.iter_mut().enumerate() {
        p.adaptive = if i % 2 == 0 {
            AdaptiveMode::Aimd { target_bits: 600 }
        } else {
            AdaptiveMode::Window { grow: 0.8, shrink: 0.5 }
        };
    }
    let mut cfg = FleetConfig::with_profiles(profiles);
    cfg.uplink_bps = 2.5e5;
    cfg.jitter_s = 0.002;
    cfg.requests_per_device = 3;
    cfg.verifier = VerifierConfig { concurrency: 2, batch_max: 4, ..Default::default() };
    cfg.seed = seed;
    cfg.record_trace = record_trace;
    cfg
}

#[test]
fn adaptive_fleet_is_bit_identical() {
    // the control plane is clock- and RNG-free: an adaptive fleet is still
    // a pure function of (config, seed)
    let a = FleetSim::new(adaptive_fleet_cfg(303, true)).run().unwrap();
    let b = FleetSim::new(adaptive_fleet_cfg(303, true)).run().unwrap();
    assert!(!a.trace.is_empty());
    assert_eq!(a.trace, b.trace, "adaptive event traces diverge");
    assert_eq!(a.digest(), b.digest(), "adaptive metrics digests differ");
    assert_eq!(a.completed, 18, "6 devices x 3 requests");

    let c = FleetSim::new(adaptive_fleet_cfg(304, true)).run().unwrap();
    assert_ne!(a.trace, c.trace, "seeds must still matter");
}

#[test]
fn off_mode_profile_matches_default_profile_digest() {
    // `adaptive: Off` routes through the control plane's Static policy.
    // This pins the *default == explicit Off* equivalence (so a future
    // change to the default adaptive mode cannot silently slip in); the
    // byte-identity of the Off path against the pre-control-plane code is
    // pinned structurally by edge::tests::knobs_path_with_static_knobs_
    // is_bit_identical (Static knobs ≡ the legacy capped path).
    let mk = |explicit_off: bool| {
        let mut base = DeviceProfile {
            policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
            max_new_tokens: 16,
            workload: Workload::Poisson { rate_hz: 3.0 },
            ..Default::default()
        };
        if explicit_off {
            base.adaptive = AdaptiveMode::Off;
        }
        let mut cfg = FleetConfig::uniform(5, base);
        cfg.uplink_bps = 1e6;
        cfg.requests_per_device = 3;
        cfg.seed = 1234;
        cfg.record_trace = true;
        cfg
    };
    let implicit = FleetSim::new(mk(false)).run().unwrap();
    let explicit = FleetSim::new(mk(true)).run().unwrap();
    assert_eq!(implicit.trace, explicit.trace);
    assert_eq!(implicit.digest(), explicit.digest());
}

#[test]
fn aimd_fleet_holds_wire_budget_where_static_overshoots() {
    let target = 600u64;
    let mk = |adaptive: AdaptiveMode| {
        // default 32-token requests: most rounds draft a full window, so
        // static's fixed knobs ship ~1.1kb/round against the 600b target
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            adaptive,
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(6, base);
        cfg.uplink_bps = 2.5e5;
        cfg.requests_per_device = 3;
        cfg.seed = 99;
        cfg
    };
    let stat = FleetSim::new(mk(AdaptiveMode::Off)).run().unwrap();
    let aimd = FleetSim::new(mk(AdaptiveMode::Aimd { target_bits: target as usize }))
        .run()
        .unwrap();
    let (stat_bpr, aimd_bpr) = (stat.mean_bits_per_round(), aimd.mean_bits_per_round());
    assert!(
        stat_bpr > target as f64,
        "static should overshoot the {target}b budget, shipped {stat_bpr:.0}"
    );
    assert!(
        aimd_bpr < stat_bpr,
        "AIMD must ship fewer bits/round than static ({aimd_bpr:.0} vs {stat_bpr:.0})"
    );
    assert!(
        aimd_bpr <= target as f64 * 1.15,
        "AIMD mean bits/round {aimd_bpr:.0} strays above the {target}b target"
    );
}

/// Acceptance: verifier budget grants measurably change `BudgetAimd`
/// behavior in a congested fleet — granted sessions converge to the
/// granted budget — and the whole thing stays a pure function of
/// (config, seed).
#[test]
fn verifier_budget_grants_throttle_an_aimd_fleet_deterministically() {
    let grant = 500u32;
    let mk = |congestion_depth: usize, grant_bits: Option<u32>| {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            max_new_tokens: 24,
            // AIMD with a generous configured target: without grants it
            // settles high, so the grant is the binding constraint
            adaptive: AdaptiveMode::Aimd { target_bits: 5000 },
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(6, base);
        cfg.uplink_bps = 1e6;
        cfg.requests_per_device = 3;
        cfg.seed = 77;
        cfg.verifier = VerifierConfig {
            concurrency: 2,
            batch_max: 4,
            congestion_depth,
            grant_bits,
            ..Default::default()
        };
        cfg
    };

    // three regimes: no signal at all, grant on every feedback frame,
    // bare congestion bit on every feedback frame
    let quiet = FleetSim::new(mk(usize::MAX, None)).run().unwrap();
    let granted = FleetSim::new(mk(0, Some(grant))).run().unwrap();
    let bit_only = FleetSim::new(mk(0, None)).run().unwrap();

    let q_bpr = quiet.mean_bits_per_round();
    let g_bpr = granted.mean_bits_per_round();
    let b_bpr = bit_only.mean_bits_per_round();
    assert!(
        q_bpr > grant as f64 * 2.0,
        "unthrottled AIMD settles far above the grant ({q_bpr:.0})"
    );
    assert!(
        g_bpr < q_bpr,
        "granted fleet must ship fewer bits/round ({g_bpr:.0} vs {q_bpr:.0})"
    );
    // convergence TO the grant, not collapse below it: AIMD oscillates
    // around the granted budget
    assert!(
        g_bpr <= grant as f64 * 1.5 && g_bpr >= grant as f64 * 0.4,
        "granted fleet converges near the {grant}b grant, got {g_bpr:.0}"
    );
    assert!(
        b_bpr < q_bpr,
        "a bare congestion bit also throttles ({b_bpr:.0} vs {q_bpr:.0})"
    );

    // the grant reaches every device's knob trace: after round 0 the
    // budget knob is the grant, not the configured 5000
    for d in &granted.per_device {
        assert!(d.knob_trace.len() >= 2, "device {} ran {} rounds", d.id, d.knob_trace.len());
        assert_eq!(d.knob_trace[0].budget_bits, 5000, "round 0 predates any feedback");
        for kp in &d.knob_trace[1..] {
            assert_eq!(kp.budget_bits, grant as usize, "device {}: {kp:?}", d.id);
        }
    }
    for d in &quiet.per_device {
        for kp in &d.knob_trace {
            assert_eq!(kp.budget_bits, 5000, "no grant: configured target everywhere");
        }
    }

    // bit-identical reproducibility from (config, seed)
    let again = FleetSim::new(mk(0, Some(grant))).run().unwrap();
    assert_eq!(granted.digest(), again.digest());
    assert_eq!(granted.downlink_bits, again.downlink_bits);
}

#[test]
fn report_aggregates_are_consistent() {
    let r = FleetSim::new(fleet_cfg(11, 1e6, false)).run().unwrap();
    assert!(r.trace.is_empty(), "trace off by default");
    let dev_completed: usize = r.per_device.iter().map(|d| d.completed).sum();
    let dev_tokens: u64 = r.per_device.iter().map(|d| d.tokens).sum();
    let dev_bits: u64 = r.per_device.iter().map(|d| d.uplink_bits).sum();
    assert_eq!(dev_completed, r.completed);
    assert_eq!(dev_tokens, r.tokens);
    assert_eq!(dev_bits, r.uplink_bits, "device ledgers must match the channel ledger");
    let batch_total: u64 = r.rejection_by_policy.iter().map(|(_, _, t)| *t).sum();
    let dev_batches: u64 = r.per_device.iter().map(|d| d.batches).sum();
    assert_eq!(batch_total, dev_batches);
    assert!(r.rejection_by_policy.len() == 3, "ksqs/csqs/dense all present");
    assert!((0.0..=1.0).contains(&r.acceptance));
    assert!(r.verify_mean_batch >= 1.0);
    // metrics registry agrees with the report
    assert_eq!(r.metrics.counter("fleet.requests_completed") as usize, r.completed);
    assert_eq!(r.metrics.counter("fleet.uplink_bits"), r.uplink_bits);
    let lat = r.metrics.histogram("fleet.request_latency_s").unwrap();
    assert_eq!(lat.count(), r.completed as u64);
}
