//! Fleet-simulator contract tests: (1) bit-identical reproducibility —
//! same seed + config must give the same event trace and the same metrics
//! digest across independent runs; (2) contention sanity — tightening the
//! shared uplink must not make the fleet faster.

use sqs_sd::fleet::{
    mixed_policy_profiles, DeviceProfile, FleetConfig, FleetSim, VerifierConfig, Workload,
};
use sqs_sd::sqs::Policy;

fn fleet_cfg(seed: u64, uplink_bps: f64, record_trace: bool) -> FleetConfig {
    let base = DeviceProfile {
        policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
        max_new_tokens: 16,
        workload: Workload::Poisson { rate_hz: 3.0 },
        ..Default::default()
    };
    let mut cfg = FleetConfig::with_profiles(mixed_policy_profiles(9, base));
    cfg.uplink_bps = uplink_bps;
    cfg.jitter_s = 0.002; // exercise the seeded jitter path too
    cfg.requests_per_device = 3;
    cfg.verifier = VerifierConfig { concurrency: 2, batch_max: 4, ..Default::default() };
    cfg.seed = seed;
    cfg.record_trace = record_trace;
    cfg
}

#[test]
fn same_seed_and_config_is_bit_identical() {
    let a = FleetSim::new(fleet_cfg(2024, 1e6, true)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(2024, 1e6, true)).run().unwrap();

    assert!(!a.trace.is_empty());
    assert_eq!(a.trace.len(), b.trace.len(), "event counts differ");
    for (i, (la, lb)) in a.trace.iter().zip(&b.trace).enumerate() {
        assert_eq!(la, lb, "traces diverge at event {i}");
    }
    assert_eq!(a.digest(), b.digest(), "metrics digests differ");

    // the digest covers floats via to_bits; spot-check raw aggregates too
    assert_eq!(a.completed, 27);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.verify_calls, b.verify_calls);
    assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits());
    assert_eq!(a.latency.mean().to_bits(), b.latency.mean().to_bits());
}

#[test]
fn different_seeds_diverge() {
    let a = FleetSim::new(fleet_cfg(1, 1e6, true)).run().unwrap();
    let b = FleetSim::new(fleet_cfg(2, 1e6, true)).run().unwrap();
    assert_ne!(a.trace, b.trace, "seeds must matter");
}

#[test]
fn halving_shared_uplink_does_not_decrease_mean_latency() {
    // Decouple the verifier (one slot per device, no coalescing) and use
    // zero jitter + open-loop arrivals so the uplink is the only coupled
    // stage: every frame's delivery can then only get later at half rate.
    let mk = |bps: f64| {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            max_new_tokens: 16,
            workload: Workload::Poisson { rate_hz: 4.0 },
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(8, base);
        cfg.uplink_bps = bps;
        cfg.jitter_s = 0.0;
        cfg.requests_per_device = 4;
        cfg.verifier = VerifierConfig { concurrency: 8, batch_max: 1, ..Default::default() };
        cfg.seed = 7;
        cfg
    };
    let full = FleetSim::new(mk(1e6)).run().unwrap();
    let half = FleetSim::new(mk(5e5)).run().unwrap();

    assert_eq!(full.completed, half.completed, "same workload either way");
    assert!(
        half.latency.mean() >= full.latency.mean() - 1e-9,
        "halving uplink capacity decreased mean latency: {} < {}",
        half.latency.mean(),
        full.latency.mean()
    );
    assert!(
        half.uplink_utilization >= full.uplink_utilization - 1e-9,
        "tighter link should be at least as utilized"
    );
    assert!(half.horizon_s >= full.horizon_s - 1e-9);
}

#[test]
fn report_aggregates_are_consistent() {
    let r = FleetSim::new(fleet_cfg(11, 1e6, false)).run().unwrap();
    assert!(r.trace.is_empty(), "trace off by default");
    let dev_completed: usize = r.per_device.iter().map(|d| d.completed).sum();
    let dev_tokens: u64 = r.per_device.iter().map(|d| d.tokens).sum();
    let dev_bits: u64 = r.per_device.iter().map(|d| d.uplink_bits).sum();
    assert_eq!(dev_completed, r.completed);
    assert_eq!(dev_tokens, r.tokens);
    assert_eq!(dev_bits, r.uplink_bits, "device ledgers must match the channel ledger");
    let batch_total: u64 = r.rejection_by_policy.iter().map(|(_, _, t)| *t).sum();
    let dev_batches: u64 = r.per_device.iter().map(|d| d.batches).sum();
    assert_eq!(batch_total, dev_batches);
    assert!(r.rejection_by_policy.len() == 3, "ksqs/csqs/dense all present");
    assert!((0.0..=1.0).contains(&r.acceptance));
    assert!(r.verify_mean_batch >= 1.0);
    // metrics registry agrees with the report
    assert_eq!(r.metrics.counter("fleet.requests_completed") as usize, r.completed);
    assert_eq!(r.metrics.counter("fleet.uplink_bits"), r.uplink_bits);
    let lat = r.metrics.summary("fleet.request_latency_s").unwrap();
    assert_eq!(lat.count(), r.completed as u64);
}
