//! End-to-end protocol-v2 sessions over a real TCP socket: handshake,
//! prompt setup, draft/feedback rounds through `StreamTransport` on both
//! ends, and the downlink-as-control-channel behavior (budget grants
//! throttling an AIMD edge).

use std::net::TcpStream;
use std::time::{Duration, Instant};

use sqs_sd::control::AdaptiveMode;
use sqs_sd::model::synthetic::SyntheticDraft;
use sqs_sd::protocol::StreamTransport;
use sqs_sd::serve::{run_soak, SoakConfig};
use sqs_sd::server::wire::{
    WireEdge, WireEdgeConfig, WireRunReport, WireServer, WireServerConfig,
};
use sqs_sd::sqs::Policy;

fn run_session_depth(
    grant: Option<u32>,
    congestion_depth: usize,
    adaptive: AdaptiveMode,
    seed: u64,
    pipeline_depth: usize,
) -> WireRunReport {
    let cfg = WireServerConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: Some(1),
        congestion_depth,
        grant_bits: grant,
        seed,
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let world = server.world().clone();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    let stream = TcpStream::connect(addr).unwrap();
    let mut transport = StreamTransport::new(stream);
    let draft = SyntheticDraft::new(world, 100_000);
    let edge_cfg = WireEdgeConfig {
        policy: Policy::KSqs { k: 8 },
        adaptive,
        pipeline_depth,
        seed,
        ..Default::default()
    };
    let mut edge = WireEdge::new(draft, edge_cfg);
    let report = edge.run(&mut transport, &[3, 1, 4], 32).unwrap();
    handle.join().unwrap();
    report
}

fn run_session(
    grant: Option<u32>,
    congestion_depth: usize,
    adaptive: AdaptiveMode,
    seed: u64,
) -> WireRunReport {
    run_session_depth(grant, congestion_depth, adaptive, seed, 1)
}

#[test]
fn tcp_session_round_trips_and_is_deterministic() {
    let r = run_session(None, usize::MAX, AdaptiveMode::Off, 42);
    assert!(r.new_tokens() >= 32, "request completed: {} tokens", r.new_tokens());
    assert!(r.batches > 0);
    assert!(r.handshake_uplink_bits > 0, "Hello bits in the ledger");
    assert!(r.handshake_downlink_bits > 0, "HelloAck bits in the ledger");
    assert!(r.uplink_bits > r.handshake_uplink_bits, "prompt + drafts follow the Hello");
    assert!(r.downlink_bits > r.handshake_downlink_bits, "feedback follows the ack");
    assert_eq!(r.grants_seen, 0, "no grants configured");
    assert_eq!(r.frame_bits.len(), r.batches);

    // same seeds on both ends => bit-identical token stream and ledgers
    let r2 = run_session(None, usize::MAX, AdaptiveMode::Off, 42);
    assert_eq!(r.tokens, r2.tokens);
    assert_eq!(r.uplink_bits, r2.uplink_bits);
    assert_eq!(r.downlink_bits, r2.downlink_bits);

    // a different seed must diverge
    let r3 = run_session(None, usize::MAX, AdaptiveMode::Off, 43);
    assert_ne!(r.tokens, r3.tokens);
}

#[test]
fn tcp_budget_grant_throttles_an_aimd_edge() {
    let grant = 400u32;
    let aimd = AdaptiveMode::Aimd { target_bits: 5000 };
    // congestion_depth 0: the server grants on every feedback frame
    let granted = run_session(Some(grant), 0, aimd, 9);
    assert!(granted.grants_seen > 0, "grants must reach the edge");
    assert!(granted.batches >= 4, "enough rounds to converge: {}", granted.batches);

    let free = run_session(None, usize::MAX, aimd, 9);

    // after the first grant lands, every frame obeys the granted budget
    // (plus header/token overhead the dist-bits budget does not cover)
    let tail = &granted.frame_bits[1..];
    let tail_mean = tail.iter().sum::<usize>() as f64 / tail.len() as f64;
    assert!(
        tail_mean <= grant as f64 * 1.6,
        "granted session must converge near the {grant}b grant, got {tail_mean:.0}"
    );
    let free_tail = &free.frame_bits[1..];
    let free_mean = free_tail.iter().sum::<usize>() as f64 / free_tail.len() as f64;
    assert!(
        tail_mean < free_mean,
        "granted sessions ship fewer bits/round than ungranted ({tail_mean:.0} vs {free_mean:.0})"
    );

    // reproducible bit-identically from (config, seed)
    let again = run_session(Some(grant), 0, aimd, 9);
    assert_eq!(granted.tokens, again.tokens);
    assert_eq!(granted.frame_bits, again.frame_bits);
    assert_eq!(granted.uplink_bits, again.uplink_bits);
}

#[test]
fn tcp_depth_one_is_bit_identical_to_the_default_config() {
    // the pipelining refactor must not move the default TCP path: an
    // explicit depth-1 session produces the same tokens and the same
    // stream ledgers as a default-config session
    let a = run_session(None, usize::MAX, AdaptiveMode::Off, 17);
    let b = run_session_depth(None, usize::MAX, AdaptiveMode::Off, 17, 1);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.frame_bits, b.frame_bits);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.downlink_bits, b.downlink_bits);
    assert_eq!(a.discarded, 0);
    assert_eq!(b.discarded, 0);
}

#[test]
fn tcp_pipelined_session_round_trips_and_is_deterministic() {
    let r = run_session_depth(None, usize::MAX, AdaptiveMode::Off, 42, 3);
    assert!(r.new_tokens() >= 32, "request completed: {} tokens", r.new_tokens());
    assert!(r.batches > 0);
    assert_eq!(r.frame_bits.len(), r.batches, "one size per verified batch");
    assert!(r.uplink_bits > r.handshake_uplink_bits);

    // bit-identical reruns from (config, seed); the pipelined stream
    // path has no virtual clock, so determinism is purely protocol-level
    let r2 = run_session_depth(None, usize::MAX, AdaptiveMode::Off, 42, 3);
    assert_eq!(r.tokens, r2.tokens);
    assert_eq!(r.uplink_bits, r2.uplink_bits);
    assert_eq!(r.downlink_bits, r2.downlink_bits);
    assert_eq!(r.discarded, r2.discarded);

    let r3 = run_session_depth(None, usize::MAX, AdaptiveMode::Off, 43, 3);
    assert_ne!(r.tokens, r3.tokens, "seeds must matter");
}

#[test]
fn tcp_handshake_rejects_a_mismatched_vocab() {
    let cfg = WireServerConfig {
        addr: "127.0.0.1:0".into(),
        vocab: 64,
        max_conns: Some(1),
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    // a client drafting over a 32-token world cannot join a 64-token server
    let other_world = sqs_sd::model::synthetic::SyntheticWorld::new(32, 0.6, 1);
    let draft = SyntheticDraft::new(other_world, 10_000);
    let mut edge = WireEdge::new(draft, WireEdgeConfig::default());
    let stream = TcpStream::connect(addr).unwrap();
    let mut transport = StreamTransport::new(stream);
    let err = edge.run(&mut transport, &[1, 2], 8);
    assert!(err.is_err(), "mismatched vocab must fail the handshake");
    handle.join().unwrap();
}

#[test]
fn tcp_soak_many_sessions_coalesce_and_conserve_grants() {
    let pool = 1u32 << 16;
    let server_cfg = WireServerConfig {
        shards: 4,
        verify_workers: 1,
        verify_batch: 16,
        // a modeled service time makes drafts pile up behind the
        // sleeping verify call, so cross-session coalescing must engage
        verify_base_s: 5e-4,
        // always-congested feedback: every frame carries a grant, so the
        // pool-conservation diagnostic sees every emission
        congestion_depth: 0,
        grant_pool_bits: Some(pool),
        seed: 11,
        ..Default::default()
    };
    let soak = SoakConfig {
        sessions: 64,
        concurrency: 64,
        max_new_tokens: 16,
        pipeline_depth: 2,
        seed: 11,
        ..Default::default()
    };
    let r = run_soak(server_cfg, soak).unwrap();
    assert_eq!(r.completed, 64, "every session completes:\n{}", r.render());
    assert_eq!(r.failed, 0, "no session may be shed:\n{}", r.render());
    assert!(r.tokens >= 64 * 16, "each session decoded its request: {} tokens", r.tokens);
    assert!(
        r.batch_max >= 2.0,
        "cross-session coalescing must engage: batch_max {}",
        r.batch_max
    );
    assert!(r.verify_windows >= r.verify_calls, "windows per call >= 1");
    assert!(r.grants_seen > 0, "adaptive grants reach the edges");
    assert!(
        r.grant_round_max_bits <= u64::from(pool),
        "summed per-round grants stay within the pool: {} > {pool}",
        r.grant_round_max_bits
    );
    assert!(r.live_peak >= 1 && r.live_peak <= 64, "live gauge bounded: {}", r.live_peak);
}

#[test]
fn tcp_handshake_rejects_sessions_over_max_sessions() {
    let cfg = WireServerConfig {
        max_conns: Some(2),
        max_sessions: 1,
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    let world = server.world().clone();
    let metrics = server.metrics();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    // the first connection holds the only session slot: it is counted
    // live from shard intake, before it even says Hello
    let first = TcpStream::connect(addr).unwrap();
    let t0 = Instant::now();
    while !metrics.gauge("sessions.live").is_some_and(|g| g.get() >= 1) {
        assert!(t0.elapsed() < Duration::from_secs(10), "intake never counted the conn");
        std::thread::sleep(Duration::from_millis(1));
    }

    let draft = SyntheticDraft::new(world, 10_000);
    let mut edge = WireEdge::new(draft, WireEdgeConfig::default());
    let stream = TcpStream::connect(addr).unwrap();
    let mut transport = StreamTransport::new(stream);
    let err = edge.run(&mut transport, &[1, 2], 8);
    assert!(err.is_err(), "second session must be nacked at max_sessions=1");

    // releasing the first slot lets the server drain and exit; its
    // disconnect must also release the live-session gauge promptly
    drop(first);
    handle.join().unwrap();
    let live = metrics.gauge("sessions.live").map_or(0, |g| g.get());
    assert_eq!(live, 0, "disconnects release their live slot");
}
