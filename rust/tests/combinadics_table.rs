//! Bit-identity pins for the table-driven (u128) combinadic fast paths
//! against the bigint reference: the wire format is defined by the bigint
//! arithmetic, so the u128 fast path must produce the *same integers* —
//! not just valid ones — across randomized (V, K), plus the overflow
//! handoff boundary where C(V, K) leaves the u128 range and the codec
//! must fall back to bigint.

use sqs_sd::codec::combinadic::{
    subset_rank, subset_rank_u128, subset_unrank, subset_unrank_u128_into,
};
use sqs_sd::codec::multiset::{
    composition_rank, composition_rank_u128, composition_unrank, composition_unrank_u128_into,
};
use sqs_sd::util::bigint::{with_binomials, BigUint};
use sqs_sd::util::binom_table::with_binom_table;
use sqs_sd::util::check::{check, Gen};

/// Exact u128 value of a BigUint, if it fits.
fn big_to_u128(x: &BigUint) -> Option<u128> {
    if x.bits() > 128 {
        return None;
    }
    let mut v: u128 = 0;
    for i in (0..x.bits()).rev() {
        v = (v << 1) | (x.bit(i) as u128);
    }
    Some(v)
}

/// Random composition of `ell` into `k` non-negative parts.
fn random_parts(g: &mut Gen, ell: u32, k: usize) -> Vec<u32> {
    let mut parts = vec![0u32; k];
    for _ in 0..ell {
        let i = g.usize(0, k - 1);
        parts[i] += 1;
    }
    parts
}

/// Randomized (V, K): wherever the table path answers at all, its rank
/// must equal the bigint rank exactly, and its unrank must reproduce the
/// subset through a dirty reused buffer.
#[test]
fn table_subset_rank_unrank_bit_identical_to_bigint() {
    check("u128 subset rank == bigint", 400, |g, _| {
        let v = g.usize(2, 300);
        let k = g.usize(1, v.min(64));
        let subset: Vec<u16> = g.subset(v, k).into_iter().map(|x| x as u16).collect();

        let big = with_binomials(|c| subset_rank(&subset, c));
        let fast = with_binom_table(|t| subset_rank_u128(&subset, t));
        match fast {
            Some(r) => {
                assert_eq!(
                    Some(r),
                    big_to_u128(&big),
                    "V={v} K={k}: table rank != bigint rank"
                );
                // unrank through a dirty reused buffer must invert exactly
                let mut out = vec![9999u16; 3];
                with_binom_table(|t| subset_unrank_u128_into(r, v, k, t, &mut out));
                assert_eq!(out, subset, "V={v} K={k}: u128 unrank broken");
                let back = with_binomials(|c| subset_unrank(big, v, k, c));
                assert_eq!(back, subset, "V={v} K={k}: bigint unrank broken");
            }
            None => {
                // the fast path may only refuse when the rank space
                // genuinely leaves u128 (or the table caps out)
                let total_bits =
                    with_binomials(|c| c.get(v as u64, k as u64).bits());
                assert!(
                    total_bits > 128,
                    "V={v} K={k}: table refused a {total_bits}-bit rank space"
                );
            }
        }
    });
}

/// Randomized compositions: same contract for the stars-and-bars codes.
#[test]
fn table_composition_rank_unrank_bit_identical_to_bigint() {
    check("u128 composition rank == bigint", 400, |g, _| {
        let k = g.usize(1, 40);
        let ell = g.int(1, 400) as u32;
        let parts = random_parts(g, ell, k);

        let big = with_binomials(|c| composition_rank(&parts, c));
        let fast = with_binom_table(|t| composition_rank_u128(&parts, t));
        match fast {
            Some(r) => {
                assert_eq!(
                    Some(r),
                    big_to_u128(&big),
                    "ell={ell} k={k}: table rank != bigint rank"
                );
                let mut divs = vec![7u16; 2];
                let mut out = vec![42u32; 5];
                with_binom_table(|t| {
                    composition_unrank_u128_into(r, ell, k, t, &mut divs, &mut out)
                });
                assert_eq!(out, parts, "ell={ell} k={k}: u128 unrank broken");
                let back = with_binomials(|c| composition_unrank(big, ell, k, c));
                assert_eq!(back, parts, "ell={ell} k={k}: bigint unrank broken");
            }
            None => {
                let total_bits = with_binomials(|c| {
                    c.get(ell as u64 + k as u64 - 1, k as u64 - 1).bits()
                });
                assert!(
                    total_bits > 128,
                    "ell={ell} k={k}: table refused a {total_bits}-bit rank space"
                );
            }
        }
    });
}

/// The overflow handoff: walk K upward at fixed V until C(V, K) crosses
/// u128.  Below the boundary the table must answer (and agree with
/// bigint); at and above it, it must return None and the bigint cache
/// must confirm the rank space really is >128 bits.  This pins the exact
/// handoff point — an off-by-one here would corrupt wire bits silently.
#[test]
fn overflow_handoff_boundary_is_exact() {
    let v = 140usize;
    let mut crossed = false;
    for k in 1..=70usize {
        let total_big = with_binomials(|c| c.get(v as u64, k as u64).clone());
        let total_fast = with_binom_table(|t| t.get(v as u64, k as u64));
        match total_fast {
            Some(t) => {
                assert!(!crossed, "table came back after overflow at K={k}");
                assert_eq!(Some(t), big_to_u128(&total_big), "K={k}");
                // the maximal subset {V-K..V-1} has the maximal rank
                // C(V,K)-1; both paths must agree on it
                let top: Vec<u16> = ((v - k) as u16..v as u16).collect();
                let r_fast =
                    with_binom_table(|tb| subset_rank_u128(&top, tb)).unwrap();
                assert_eq!(r_fast, t - 1, "K={k}: max rank must be C(V,K)-1");
                let r_big = with_binomials(|c| subset_rank(&top, c));
                assert_eq!(Some(r_fast), big_to_u128(&r_big), "K={k}");
            }
            None => {
                crossed = true;
                assert!(
                    total_big.bits() > 128,
                    "K={k}: table refused a {}-bit binomial",
                    total_big.bits()
                );
                // the codec-facing entry points must refuse too, so the
                // frame codec falls back to bigint for these widths
                let top: Vec<u16> = ((v - k) as u16..v as u16).collect();
                assert_eq!(
                    with_binom_table(|tb| subset_rank_u128(&top, tb)),
                    None,
                    "K={k}: subset_rank_u128 must hand off past the boundary"
                );
            }
        }
    }
    assert!(crossed, "C(140, K) must cross u128 somewhere in K<=70");
}

/// Table caps (MAX_N / MAX_K): probes beyond the dense-row bounds report
/// None (bigint fallback) instead of growing without limit — even when
/// the value itself would fit u128 easily.
#[test]
fn table_caps_hand_off_even_when_value_fits() {
    let over_n = (1u64 << 16) + 1;
    assert_eq!(with_binom_table(|t| t.get(over_n, 1)), None);
    assert_eq!(with_binom_table(|t| t.get(1000, 513)), None);
    // in-cap probes still answer
    assert_eq!(with_binom_table(|t| t.get(1000, 2)), Some(499_500));
    // k > n stays a hard zero, not an overflow
    assert_eq!(with_binom_table(|t| t.get(3, 7)), Some(0));
}
