//! Flight-recorder determinism: a trace is a pure function of
//! (config, seed) on every simulated path.  Two identically-configured
//! runs must export bit-identical JSONL — the `tb` field carries raw
//! `f64::to_bits`, so even formatting cannot hide a divergence — and a
//! different seed must change the recording.  Also pins the export
//! schema the CI smoke job checks (required keys, required event kinds,
//! non-decreasing timestamps, parseable Chrome JSON).

use std::collections::BTreeSet;

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::{SdSession, SessionConfig, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetSim, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;
use sqs_sd::trace::{JsonlTracer, TraceSink};
use sqs_sd::util::json::Json;

/// Run a small contended fleet (pipelined, with trees) under a
/// `JsonlTracer` and return (JSONL, chrome JSON).
fn fleet_trace(seed: u64) -> (String, String) {
    let base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        temp: 0.8,
        max_new_tokens: 16,
        max_batch_drafts: 4,
        workload: Workload::Poisson { rate_hz: 4.0 },
        pipeline_depth: 2,
        tree_branching: 2,
        ..Default::default()
    };
    let mut cfg = FleetConfig::uniform(4, base);
    cfg.mismatch = 0.6;
    cfg.requests_per_device = 2;
    cfg.seed = seed;
    let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
    FleetSim::new(cfg).with_tracer(sink).run().unwrap();
    let tr = tracer.lock().unwrap();
    (tr.jsonl(), tr.chrome_json())
}

/// Run one pipelined tree session under a tracer and return its JSONL.
fn session_trace(seed: u64) -> String {
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.030,
        jitter_s: 0.0,
    };
    let world = SyntheticWorld::new(64, 0.6, 2024);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 6, 1_000_000);
    let cfg = SessionConfig {
        policy: Policy::KSqs { k: 8 },
        temp: 0.9,
        max_new_tokens: 48,
        max_batch_drafts: 6,
        seed,
        timing: TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 },
        pipeline_depth: 3,
        tree_branching: 2,
        ..Default::default()
    };
    let mut sess = SdSession::new(draft, target, SimulatedLink::new(link, seed), cfg);
    let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
    sess.set_tracer(sink);
    sess.run(&[7, 21, 42]).unwrap();
    let out = tracer.lock().unwrap().jsonl();
    out
}

/// Schema every exported line must satisfy; returns the kinds seen.
fn check_jsonl_schema(jsonl: &str) -> BTreeSet<String> {
    assert!(!jsonl.is_empty(), "trace must not be empty");
    let mut kinds = BTreeSet::new();
    let mut last_t = f64::NEG_INFINITY;
    for line in jsonl.lines() {
        let j = Json::parse(line).expect("every trace line parses as JSON");
        for key in ["actor", "kind", "seq", "t", "tb"] {
            assert!(j.get(key).is_some(), "trace line missing '{key}': {line}");
        }
        let t = j.get("t").unwrap().as_f64().unwrap();
        assert!(t >= last_t, "exported timestamps must be non-decreasing");
        last_t = t;
        kinds.insert(j.get("kind").unwrap().as_str().unwrap().to_string());
    }
    kinds
}

#[test]
fn fleet_trace_is_bit_identical_across_runs() {
    let (a_jsonl, a_chrome) = fleet_trace(3);
    let (b_jsonl, b_chrome) = fleet_trace(3);
    assert!(!a_jsonl.is_empty());
    assert_eq!(a_jsonl, b_jsonl, "same (config, seed) must replay bit-identically");
    assert_eq!(a_chrome, b_chrome);
}

#[test]
fn fleet_trace_depends_on_the_seed() {
    let (a, _) = fleet_trace(3);
    let (b, _) = fleet_trace(4);
    assert_ne!(a, b, "different seeds must produce different recordings");
}

#[test]
fn fleet_trace_covers_the_event_taxonomy() {
    let (jsonl, chrome) = fleet_trace(3);
    let kinds = check_jsonl_schema(&jsonl);
    for k in ["draft_sent", "frame_tx", "frame_rx", "verify_start", "verify_end", "feedback_applied"]
    {
        assert!(kinds.contains(k), "fleet trace missing kind '{k}' (saw {kinds:?})");
    }
    let j = Json::parse(&chrome).expect("chrome export parses");
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(evs.len() > kinds.len(), "chrome export must carry the events");
}

#[test]
fn session_trace_is_bit_identical_across_runs() {
    let a = session_trace(11);
    let b = session_trace(11);
    assert_eq!(a, b, "session trace must be a pure function of (config, seed)");
    let kinds = check_jsonl_schema(&a);
    for k in ["draft_sent", "frame_tx", "frame_rx", "verify_start", "verify_end", "feedback_applied"]
    {
        assert!(kinds.contains(k), "session trace missing kind '{k}' (saw {kinds:?})");
    }
    assert_ne!(a, session_trace(12));
}

#[test]
fn untraced_runs_are_unperturbed_by_a_tracer() {
    // the same fleet with and without a sink must produce the same
    // report — instrumentation is observational by construction
    let cfg = || {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            temp: 0.8,
            max_new_tokens: 16,
            max_batch_drafts: 4,
            workload: Workload::Poisson { rate_hz: 4.0 },
            pipeline_depth: 2,
            tree_branching: 2,
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(3, base);
        cfg.mismatch = 0.6;
        cfg.requests_per_device = 2;
        cfg.seed = 5;
        cfg
    };
    let plain = FleetSim::new(cfg()).run().unwrap();
    let (sink, _tracer) = TraceSink::shared(JsonlTracer::new());
    let traced = FleetSim::new(cfg()).with_tracer(sink).run().unwrap();
    assert_eq!(plain.completed, traced.completed);
    assert_eq!(plain.latency.count(), traced.latency.count());
    assert_eq!(
        plain.latency.mean().to_bits(),
        traced.latency.mean().to_bits(),
        "tracing must not perturb the simulation"
    );
}
