//! Offline-analyzer and rejection-attribution contracts.
//!
//! - The analyzer is a pure function of the trace bytes: two passes over
//!   the same recording produce bit-identical report JSON and CSV, and
//!   the report schema is pinned (CI diffs the key set against a
//!   checked-in baseline).
//! - Attribution is consistent end-to-end: every `reject_attrib` event
//!   splits one rejection into mismatch + distortion shares that sum to
//!   1, the session/fleet rollups agree with the event stream, and the
//!   measured compression distortion stays within the paper's bound
//!   |TV(q, q̂) − α| ≤ K/(4ℓ) (Lemma 1 + eq. 20), pinned here across
//!   random synthetic configs.

use sqs_sd::analysis::{analyze_jsonl, SCHEMA};
use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::{SdSession, SessionConfig, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetReport, FleetSim, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;
use sqs_sd::trace::{JsonlTracer, TraceSink};
use sqs_sd::util::check::check;
use sqs_sd::util::json::Json;

/// Contended fleet under a tracer; returns (JSONL, report).
fn fleet_trace(seed: u64) -> (String, FleetReport) {
    let base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        temp: 0.8,
        max_new_tokens: 16,
        max_batch_drafts: 4,
        workload: Workload::Poisson { rate_hz: 4.0 },
        pipeline_depth: 2,
        tree_branching: 2,
        ..Default::default()
    };
    let mut cfg = FleetConfig::uniform(4, base);
    cfg.mismatch = 0.6;
    cfg.requests_per_device = 2;
    cfg.seed = seed;
    let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
    let report = FleetSim::new(cfg).with_tracer(sink).run().unwrap();
    let jsonl = tracer.lock().unwrap().jsonl();
    (jsonl, report)
}

fn count_kind(jsonl: &str, kind: &str) -> u64 {
    jsonl.lines().filter(|l| l.contains(&format!("\"kind\":\"{kind}\""))).count() as u64
}

#[test]
fn analyzer_report_is_bit_identical_and_schema_pinned() {
    let (jsonl, _) = fleet_trace(3);
    let a = analyze_jsonl(&jsonl).unwrap();
    let b = analyze_jsonl(&jsonl).unwrap();
    let (aj, bj) = (a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    assert_eq!(aj, bj, "report JSON must be a pure function of the trace bytes");
    assert_eq!(a.to_csv(), b.to_csv());

    let j = Json::parse(&aj).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
    for key in ["events", "trace_dropped", "span_s", "actors", "totals", "rejection",
                "knob_timeline"]
    {
        assert!(j.get(key).is_some(), "report missing '{key}'");
    }
    let totals = j.get("totals").unwrap();
    for key in ["draft_s", "queue_wait_s", "uplink_air_s", "verify_s", "bubble_s",
                "discards", "rollbacks"]
    {
        assert!(totals.get(key).is_some(), "totals missing '{key}'");
    }
    // the contended fleet exercises the whole stage taxonomy
    assert!(totals.get("draft_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(totals.get("verify_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("events").unwrap().as_f64().unwrap() as u64
            == jsonl.lines().count() as u64);
}

#[test]
fn analyzer_rejection_rollup_matches_fleet_report() {
    let (jsonl, report) = fleet_trace(3);
    let attribs = count_kind(&jsonl, "reject_attrib");
    assert!(attribs > 0, "contended fleet must attribute some rejections");
    assert_eq!(report.reject_mismatch + report.reject_distortion, attribs);

    let r = analyze_jsonl(&jsonl).unwrap();
    assert_eq!(r.attributed(), attribs);
    let j = r.to_json();
    let rej = j.get("rejection").unwrap();
    let mm = rej.get("mass_mismatch").unwrap().as_f64().unwrap();
    let dm = rej.get("mass_distortion").unwrap().as_f64().unwrap();
    assert!((mm - report.reject_mass_mismatch).abs() < 1e-9);
    assert!((dm - report.reject_mass_distortion).abs() < 1e-9);
    // shares split whole rejections: the masses sum back to the count
    assert!((mm + dm - attribs as f64).abs() < 1e-6, "{mm} + {dm} != {attribs}");

    // the metrics plane carries the same pre-registered rollups
    let m = report.metrics.to_json();
    assert_eq!(
        m.get("counter.reject.mismatch").unwrap().as_f64().unwrap() as u64,
        report.reject_mismatch
    );
    assert_eq!(
        m.get("counter.reject.distortion").unwrap().as_f64().unwrap() as u64,
        report.reject_distortion
    );
    let alpha_n = m.path(&["hist.alpha", "n"]).unwrap().as_f64().unwrap() as u64;
    assert!(alpha_n > 0, "every drafted node observes hist.alpha");
}

#[test]
fn session_engine_rollup_matches_its_trace() {
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.030,
        jitter_s: 0.0,
    };
    let world = SyntheticWorld::new(64, 0.8, 2024);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 6, 1_000_000);
    let cfg = SessionConfig {
        policy: Policy::KSqs { k: 8 },
        temp: 0.9,
        max_new_tokens: 48,
        max_batch_drafts: 6,
        seed: 11,
        timing: TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 },
        pipeline_depth: 3,
        tree_branching: 2,
        ..Default::default()
    };
    let mut sess = SdSession::new(draft, target, SimulatedLink::new(link, 11), cfg);
    let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
    sess.set_tracer(sink);
    let res = sess.run(&[7, 21, 42]).unwrap();
    let jsonl = tracer.lock().unwrap().jsonl();

    let attribs = count_kind(&jsonl, "reject_attrib");
    assert!(attribs > 0, "high-mismatch session must attribute rejections");
    assert_eq!(res.reject_mismatch + res.reject_distortion, attribs);
    assert!(
        (res.reject_mass_mismatch + res.reject_mass_distortion - attribs as f64).abs() < 1e-6
    );
    assert!(res.mean_alpha >= 0.0 && res.mean_alpha < 1.0);

    let r = analyze_jsonl(&jsonl).unwrap();
    assert_eq!(r.attributed(), attribs);
}

/// Property (Lemma 1 + eq. 20, end to end): every attributed rejection
/// decomposes into shares that sum to one, the rollups agree with the
/// event stream, and the measured distortion basis tv = TV(q, q̂) stays
/// within K/(4ℓ) of the dropped mass α at the rejected position.
#[test]
fn attribution_mass_is_conserved_across_synthetic_configs() {
    check("attribution mass conserved", 10, |g, case| {
        let vocab = *g.pick(&[32usize, 64]);
        let ell = g.usize(50, 400) as u32;
        let depth = g.usize(1, 3);
        let branching = if depth >= 2 && g.bool() { 2 } else { 1 };
        let base = DeviceProfile {
            policy: Policy::KSqs { k: g.usize(4, 16) },
            temp: g.f32(0.6, 1.0),
            ell,
            max_new_tokens: 12,
            max_batch_drafts: 4,
            workload: Workload::Poisson { rate_hz: 4.0 },
            pipeline_depth: depth,
            tree_branching: branching,
            ..Default::default()
        };
        let n = g.usize(2, 3);
        let mut cfg = FleetConfig::uniform(n, base);
        cfg.vocab = vocab;
        cfg.mismatch = g.f64(0.4, 0.9);
        cfg.requests_per_device = 2;
        cfg.seed = 0xA11A ^ case as u64;
        let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
        let report = FleetSim::new(cfg).with_tracer(sink).run().unwrap();
        let jsonl = tracer.lock().unwrap().jsonl();

        let mut attribs = 0u64;
        let mut mass_mismatch = 0.0f64;
        let mut mass_distortion = 0.0f64;
        let slack = vocab as f64 / (4.0 * ell as f64) + 3e-3;
        for line in jsonl.lines() {
            let j = Json::parse(line).unwrap();
            if j.get("kind").unwrap().as_str() != Some("reject_attrib") {
                continue;
            }
            attribs += 1;
            let alpha = j.get("alpha").unwrap().as_f64().unwrap();
            let tv = j.get("tv").unwrap().as_f64().unwrap();
            let rhat = j.get("rhat").unwrap().as_f64().unwrap();
            let mm = j.get("mismatch").unwrap().as_f64().unwrap();
            let dm = j.get("distortion").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&mm), "mismatch share {mm}");
            assert!((0.0..=1.0).contains(&dm), "distortion share {dm}");
            assert!((mm + dm - 1.0).abs() < 1e-9, "shares must sum to 1: {mm} + {dm}");
            assert!((0.0..=1.0).contains(&rhat), "rhat {rhat}");
            assert!(alpha >= 0.0 && tv >= 0.0);
            // |TV(q, q̂) − α| ≤ TV(q̄, q̂) ≤ K/(4ℓ), plus f32 headroom
            assert!(
                (tv - alpha).abs() <= slack,
                "|tv − alpha| = |{tv} − {alpha}| > K/(4ℓ) slack {slack}"
            );
            mass_mismatch += mm;
            mass_distortion += dm;
        }
        // rollups agree with the event stream exactly (same arithmetic)
        assert_eq!(report.reject_mismatch + report.reject_distortion, attribs);
        assert!((report.reject_mass_mismatch - mass_mismatch).abs() < 1e-9);
        assert!((report.reject_mass_distortion - mass_distortion).abs() < 1e-9);
        // and the attributed mass reproduces the attributed-rejection
        // count: nothing over- or under-counted
        assert!(
            (mass_mismatch + mass_distortion - attribs as f64).abs() < 1e-6,
            "mass {mass_mismatch}+{mass_distortion} != attributed {attribs}"
        );
    });
}
