//! Lossy-channel resilience (protocol v5): the determinism pins at
//! loss = 0, seeded-loss recovery through retransmits and epoch
//! resyncs, fleet churn with resume reconnects, and the TCP recovery
//! machinery (resume tokens, go-back-N nacks, duplicate-draft replay,
//! read deadlines) against the real sharded endpoint.
//!
//! The contract under test is DESIGN.md §16 / docs/PROTOCOL.md §7.

use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use sqs_sd::channel::{LinkConfig, LossModel, SimulatedLink};
use sqs_sd::codec::{DraftFrame, DraftToken};
use sqs_sd::coordinator::session::{SdSession, SessionConfig, SessionResult, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetReport, FleetSim, VerifierConfig, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::protocol::{
    Control, Direction, Frame, SeqDraft, StreamTransport, Transport, WireCodec,
    NO_RESUME_TOKEN, PROTOCOL_V5,
};
use sqs_sd::server::wire::{WireEdge, WireEdgeConfig, WireServer, WireServerConfig};
use sqs_sd::sqs::bits::SchemeBits;
use sqs_sd::sqs::{sparse_quantize, Policy, Sparsifier};

fn modeled() -> TimingMode {
    TimingMode::Modeled { slm_step_s: 1e-4, llm_call_s: 1e-3 }
}

/// One synthetic session over a link carrying `loss` on both directions.
fn run_lossy_session(loss: LossModel, seed: u64, max_new: usize) -> SessionResult {
    let world = SyntheticWorld::new(32, 0.7, 5);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
    let link = SimulatedLink::new(LinkConfig::default(), seed)
        .with_uplink_loss(loss)
        .with_downlink_loss(loss);
    let cfg = SessionConfig {
        max_new_tokens: max_new,
        seed,
        timing: modeled(),
        // generous ARQ budget: the test asserts *recovery*, not the
        // budget-exhaustion error path
        max_retransmits: 10,
        ..Default::default()
    };
    SdSession::new(draft, target, link, cfg).run(&[3, 1, 4]).unwrap()
}

// ---------------------------------------------------------------------
// session layer
// ---------------------------------------------------------------------

#[test]
fn loss_zero_is_bit_identical_and_draws_no_recovery() {
    // an explicit LossModel::None must be byte-for-byte the same session
    // as a link never touched by the loss API: None draws no randomness
    let plain = {
        let world = SyntheticWorld::new(32, 0.7, 5);
        let draft = SyntheticDraft::new(world.clone(), 1_000_000);
        let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
        let link = SimulatedLink::new(LinkConfig::default(), 42);
        let cfg = SessionConfig {
            max_new_tokens: 32,
            seed: 42,
            timing: modeled(),
            max_retransmits: 10,
            ..Default::default()
        };
        SdSession::new(draft, target, link, cfg).run(&[3, 1, 4]).unwrap()
    };
    let with_none = run_lossy_session(LossModel::None, 42, 32);
    assert_eq!(plain.tokens, with_none.tokens);
    assert_eq!(plain.uplink_bits, with_none.uplink_bits);
    assert_eq!(plain.downlink_bits, with_none.downlink_bits);
    assert_eq!(with_none.retransmits, 0, "lossless sessions never retransmit");
    assert_eq!(with_none.loss_resyncs, 0);
    assert_eq!(with_none.t_recovery_s, 0.0, "no recovery time at loss = 0");
}

#[test]
fn lossy_session_recovers_and_is_deterministic() {
    let loss = LossModel::Iid { p: 0.2 };

    // recovery engages somewhere across a handful of seeds (each seed is
    // deterministic; the union makes the assertion seed-robust)
    let mut total_retransmits = 0u64;
    for seed in 1..=4u64 {
        let r = run_lossy_session(loss, seed, 48);
        assert!(
            r.new_tokens() >= 48,
            "seed {seed}: lossy session must still complete, got {}",
            r.new_tokens()
        );
        if r.retransmits > 0 {
            assert!(r.t_recovery_s > 0.0, "retransmits must cost recovery time");
        }
        total_retransmits += r.retransmits;
    }
    assert!(total_retransmits > 0, "a 20% loss law must drop something");

    // same (config, seed) => bit-identical run, recovery counters included
    let a = run_lossy_session(loss, 3, 48);
    let b = run_lossy_session(loss, 3, 48);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.downlink_bits, b.downlink_bits);
    assert_eq!(a.retransmits, b.retransmits);
    assert_eq!(a.loss_resyncs, b.loss_resyncs);
    assert_eq!(a.t_recovery_s, b.t_recovery_s);
}

// ---------------------------------------------------------------------
// fleet layer
// ---------------------------------------------------------------------

fn run_fleet(loss: LossModel, churn_every: u64, seed: u64) -> FleetReport {
    let base = DeviceProfile {
        policy: Policy::KSqs { k: 8 },
        max_new_tokens: 16,
        workload: Workload::ClosedLoop { think_s: 0.01 },
        churn_drop_every: churn_every,
        ..Default::default()
    };
    let mut cfg = FleetConfig::uniform(4, base);
    cfg.uplink_bps = 5e5;
    cfg.loss = loss;
    cfg.requests_per_device = 3;
    cfg.verifier = VerifierConfig { concurrency: 2, batch_max: 4, ..Default::default() };
    cfg.seed = seed;
    FleetSim::new(cfg).run().unwrap()
}

#[test]
fn fleet_at_loss_zero_is_quiet_and_bit_identical() {
    let a = run_fleet(LossModel::None, 0, 7);
    assert_eq!(a.completed, 4 * 3, "every request completes");
    assert_eq!(a.retransmits, 0);
    assert_eq!(a.churn_drops, 0);
    assert_eq!(a.churn_reconnects, 0);

    let b = run_fleet(LossModel::None, 0, 7);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.latency.p50().to_bits(), b.latency.p50().to_bits());
}

#[test]
fn fleet_under_burst_loss_retransmits_and_completes() {
    let ge = LossModel::GilbertElliott {
        p_enter_bad: 0.05,
        p_exit_bad: 0.4,
        loss_good: 0.02,
        loss_bad: 0.5,
    };
    let a = run_fleet(ge, 0, 7);
    assert_eq!(a.completed, 4 * 3, "loss must not shed requests");
    assert!(a.retransmits > 0, "a bursty uplink must force retransmits");

    let b = run_fleet(ge, 0, 7);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.uplink_bits, b.uplink_bits);
    assert_eq!(a.retransmits, b.retransmits, "recovery itself is deterministic");
}

#[test]
fn fleet_churn_drops_resume_and_complete() {
    let r = run_fleet(LossModel::None, 2, 7);
    assert_eq!(r.completed, 4 * 3, "churned devices finish their requests");
    assert!(r.churn_drops > 0, "churn_drop_every=2 must trigger drops");
    assert_eq!(
        r.churn_reconnects, r.churn_drops,
        "every drop resumes (nothing evicts the table in a 4-device run)"
    );
}

// ---------------------------------------------------------------------
// TCP layer
// ---------------------------------------------------------------------

fn bind_server(max_conns: usize, seed: u64) -> (WireServer, std::net::SocketAddr) {
    let cfg = WireServerConfig {
        addr: "127.0.0.1:0".into(),
        max_conns: Some(max_conns),
        seed,
        ..Default::default()
    };
    let server = WireServer::bind(cfg).unwrap();
    let addr = server.local_addr().unwrap();
    (server, addr)
}

fn run_wire_session(loss_recovery: bool, seed: u64) -> sqs_sd::server::wire::WireRunReport {
    let (server, addr) = bind_server(1, seed);
    let world = server.world().clone();
    let handle = std::thread::spawn(move || server.serve().unwrap());
    let mut transport = StreamTransport::new(TcpStream::connect(addr).unwrap());
    let draft = SyntheticDraft::new(world, 100_000);
    let cfg = WireEdgeConfig { pipeline_depth: 2, loss_recovery, seed, ..Default::default() };
    let mut edge = WireEdge::new(draft, cfg);
    let report = edge.run(&mut transport, &[3, 1, 4], 24).unwrap();
    handle.join().unwrap();
    report
}

#[test]
fn tcp_v5_session_gets_a_resume_token_and_matches_v3_bit_for_bit() {
    let v3 = run_wire_session(false, 42);
    assert_eq!(v3.resume_token, NO_RESUME_TOKEN, "pre-v5 sessions get no token");
    assert!(!v3.resumed);

    let v5 = run_wire_session(true, 42);
    assert_ne!(v5.resume_token, NO_RESUME_TOKEN, "v5 sessions always get a token");
    assert!(!v5.resumed, "nothing presented, nothing restored");

    // the handshake's resume fields are fixed-width and always present,
    // so opting into v5 moves no payload bits at loss = 0
    assert_eq!(v3.tokens, v5.tokens);
    assert_eq!(v3.uplink_bits, v5.uplink_bits);
    assert_eq!(v3.downlink_bits, v5.downlink_bits);
    assert_eq!(v3.frame_bits, v5.frame_bits);
}

/// Handshake + prompt by hand, then vanish without a `Bye` — the only
/// way to make the server park resumable state from the outside.
fn handshake_and_abandon(addr: std::net::SocketAddr, prompt: &[u16]) -> u32 {
    let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    codec.set_version(PROTOCOL_V5);
    let mut t = StreamTransport::new(TcpStream::connect(addr).unwrap());
    let hello = codec.hello().unwrap();
    t.send_frame(Direction::Up, &Frame::Hello(hello), &mut codec, 0.0).unwrap();
    let ack = match t.recv_frame(Direction::Down, &mut codec).unwrap() {
        Frame::HelloAck(a) => a,
        other => panic!("expected HelloAck, got {}", other.name()),
    };
    assert!(ack.ok);
    assert_eq!(ack.version, PROTOCOL_V5);
    assert_ne!(ack.resume_token, NO_RESUME_TOKEN);
    codec.set_version(ack.version);
    let prompt_frame = Frame::Control(Control::Prompt(prompt.to_vec()));
    t.send_frame(Direction::Up, &prompt_frame, &mut codec, 0.0).unwrap();
    // dropping the stream here (no Bye) is the churn event: the server
    // must park this session's context under the token it handed out
    ack.resume_token
}

#[test]
fn tcp_resume_restores_context_and_a_stale_token_restarts_clean() {
    let (server, addr) = bind_server(3, 9);
    let world = server.world().clone();
    let handle = std::thread::spawn(move || server.serve().unwrap());
    let prompt = [3u16, 1, 4];

    let token = handshake_and_abandon(addr, &prompt);
    // let the shard notice the disconnect and file the resume state
    std::thread::sleep(Duration::from_millis(200));

    // reconnect presenting the token: the server restores the committed
    // context and the prompt round trip is skipped
    let draft = SyntheticDraft::new(world.clone(), 100_000);
    let cfg = WireEdgeConfig { loss_recovery: true, seed: 9, ..Default::default() };
    let mut edge = WireEdge::new(draft, cfg);
    edge.set_resume_token(token);
    let mut transport = StreamTransport::new(TcpStream::connect(addr).unwrap());
    let resumed = edge.run(&mut transport, &prompt, 16).unwrap();
    assert!(resumed.resumed, "a parked token must restore the session");
    assert!(resumed.new_tokens() >= 16, "the resumed session keeps decoding");

    // a token the server never issued (or already consumed) must fall
    // back to a clean fresh session, never a half-restored one
    let draft = SyntheticDraft::new(world, 100_000);
    let cfg = WireEdgeConfig { loss_recovery: true, seed: 10, ..Default::default() };
    let mut edge = WireEdge::new(draft, cfg);
    edge.set_resume_token(0x5EED_F00D);
    let mut transport = StreamTransport::new(TcpStream::connect(addr).unwrap());
    let fresh = edge.run(&mut transport, &prompt, 8).unwrap();
    assert!(!fresh.resumed, "an unknown token must not claim a restore");
    assert!(fresh.new_tokens() >= 8, "the fallback is a full clean session");

    handle.join().unwrap();
}

/// A valid 3-token draft over the server's default codec config
/// (vocab 64, ell 100, top-8), good enough to decode and verify.
fn sample_draft(batch_id: u32, gen_seed: u64) -> DraftFrame {
    let mut g = sqs_sd::util::check::Gen { rng: sqs_sd::util::rng::Pcg64::new(gen_seed, 0) };
    let tokens: Vec<DraftToken> = (0..3)
        .map(|_| {
            let q = g.probs(64, 2.0);
            let quant = sparse_quantize(&q, &Sparsifier::top_k(8), 100);
            let token = quant.support[0];
            DraftToken { quant, token }
        })
        .collect();
    DraftFrame { batch_id, tokens }
}

#[test]
fn tcp_seq_gap_draws_a_nack_and_a_duplicate_replays_cached_feedback() {
    let (server, addr) = bind_server(1, 5);
    let handle = std::thread::spawn(move || server.serve().unwrap());

    let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    codec.set_version(PROTOCOL_V5);
    let mut t = StreamTransport::new(TcpStream::connect(addr).unwrap());
    let hello = codec.hello().unwrap();
    t.send_frame(Direction::Up, &Frame::Hello(hello), &mut codec, 0.0).unwrap();
    let ack = match t.recv_frame(Direction::Down, &mut codec).unwrap() {
        Frame::HelloAck(a) => a,
        other => panic!("expected HelloAck, got {}", other.name()),
    };
    assert!(ack.ok && ack.version == PROTOCOL_V5);
    codec.set_version(ack.version);
    t.send_frame(Direction::Up, &Frame::Control(Control::Prompt(vec![3, 1, 4])), &mut codec, 0.0)
        .unwrap();

    // a draft arriving with seq 1 while the server expects 0 is a gap:
    // go-back-N drops it and nacks the first missing sequence
    let skipped = Frame::DraftSeq(SeqDraft { seq: 1, epoch: 0, frame: sample_draft(1, 71) });
    t.send_frame(Direction::Up, &skipped, &mut codec, 0.0).unwrap();
    let fb = match t.recv_frame(Direction::Down, &mut codec).unwrap() {
        Frame::Feedback(fb) => fb,
        other => panic!("expected Feedback, got {}", other.name()),
    };
    let nack = fb.nack().expect("a gap must ride a Nack extension");
    assert_eq!(nack.seq, 0, "go-back-N names the first missing seq");
    assert_eq!(nack.epoch, 0);
    assert_eq!(fb.accepted, 0, "a pure nack verifies nothing");

    // replaying from the gap verifies normally and acks seq 0
    let first = Frame::DraftSeq(SeqDraft { seq: 0, epoch: 0, frame: sample_draft(0, 72) });
    t.send_frame(Direction::Up, &first, &mut codec, 0.0).unwrap();
    let verdict = match t.recv_frame(Direction::Down, &mut codec).unwrap() {
        Frame::Feedback(fb) => fb,
        other => panic!("expected Feedback, got {}", other.name()),
    };
    let (seq, _) = verdict.acked_seq().expect("a verified draft must carry an ack");
    assert_eq!(seq, 0);

    // a duplicate of an answered seq must NOT verify again (that would
    // advance the sampler chain); the cached verdict replays verbatim
    let dup = Frame::DraftSeq(SeqDraft { seq: 0, epoch: 0, frame: sample_draft(0, 72) });
    t.send_frame(Direction::Up, &dup, &mut codec, 0.0).unwrap();
    let replay = match t.recv_frame(Direction::Down, &mut codec).unwrap() {
        Frame::Feedback(fb) => fb,
        other => panic!("expected Feedback, got {}", other.name()),
    };
    assert_eq!(replay, verdict, "duplicate drafts replay the cached feedback bit-for-bit");

    t.send_frame(Direction::Up, &Frame::Control(Control::Bye), &mut codec, 0.0).unwrap();
    drop(t);
    handle.join().unwrap();
}

#[test]
fn tcp_read_deadline_turns_a_silent_server_into_a_clean_error() {
    // a listener that accepts and never speaks: without a deadline the
    // edge would block in read_exact forever
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hold = std::thread::spawn(move || {
        let (sock, _) = listener.accept().unwrap();
        std::thread::sleep(Duration::from_secs(1));
        drop(sock);
    });

    let world = SyntheticWorld::new(64, 0.6, 2024);
    let draft = SyntheticDraft::new(world, 100_000);
    let mut edge = WireEdge::new(draft, WireEdgeConfig::default());
    let mut transport = sqs_sd::server::wire::connect_edge(addr, 0.3).unwrap();
    let err = edge.run(&mut transport, &[3, 1, 4], 8).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("timed out"),
        "silence past the deadline must surface as a timeout, got: {msg}"
    );
    hold.join().unwrap();
}
