//! End-to-end speculative decoding over the real PJRT stack (requires
//! `make artifacts`; run with --test-threads=1, see Makefile).

#![cfg(feature = "pjrt")]

use sqs_sd::channel::LinkConfig;
use sqs_sd::coordinator::{PjrtStack, SessionConfig, TimingMode};
use sqs_sd::model::encode;
use sqs_sd::runtime::Manifest;
use sqs_sd::sqs::Policy;

fn stack_or_skip() -> Option<PjrtStack> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built");
        return None;
    }
    Some(PjrtStack::load(1 << 30).expect("stack loads"))
}

#[test]
fn full_sd_session_ksqs_and_csqs() {
    let Some(stack) = stack_or_skip() else { return };
    let prompt = encode("The capital of France is");

    for policy in [
        Policy::KSqs { k: 8 },
        Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
    ] {
        let cfg = SessionConfig {
            policy,
            temp: 0.3,
            max_new_tokens: 24,
            seed: 42,
            timing: TimingMode::Measured,
            ..Default::default()
        };
        let mut sess = stack.session(LinkConfig::default(), cfg);
        let res = sess.run(&prompt).unwrap();

        assert!(res.new_tokens() >= 24, "{}: too few tokens", policy.name());
        assert!(!res.batches.is_empty());
        let rr = res.resampling_rate();
        assert!((0.0..=1.0).contains(&rr));
        assert!(res.total_time_s > 0.0);
        assert!(res.uplink_bits > 0);
        for b in &res.batches {
            assert!(b.dist_bits <= 5000 || b.drafted == 1);
        }
        let text = sqs_sd::model::decode(&res.tokens[res.prompt_len..]);
        // low temperature on a memorized corpus: mostly printable English
        let printable = text.bytes().filter(|b| (32..127).contains(b)).count();
        assert!(
            printable * 10 >= text.len() * 8,
            "{}: output not mostly printable: {text:?}", policy.name()
        );
        eprintln!("{}: {:?} (rr={:.3}, accept={:.3}, bits/tok={:.0})",
                  policy.name(), text, rr, res.acceptance_rate(),
                  res.bits_per_token());
    }
}

#[test]
fn csqs_certificate_on_pjrt() {
    let Some(stack) = stack_or_skip() else { return };
    let cfg = SessionConfig {
        policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
        temp: 0.8,
        max_new_tokens: 48,
        seed: 3,
        ..Default::default()
    };
    let mut sess = stack.session(LinkConfig::default(), cfg);
    let res = sess.run(&encode("Once there was a fox who")).unwrap();
    let emp = res.conformal_empirical_alpha.unwrap();
    let bound = res.conformal_bound.unwrap();
    assert!(emp <= bound + 1e-9, "Theorem 2 violated on PJRT: {emp} > {bound}");
}

#[test]
fn ar_baseline_runs_and_sd_saves_llm_calls() {
    let Some(stack) = stack_or_skip() else { return };
    let prompt = encode("A distributed system is");

    let mut ar = stack.ar_baseline(LinkConfig::default(), 0.3, 7, TimingMode::Measured);
    let res_ar = ar.run(&prompt, 16).unwrap();
    assert_eq!(res_ar.new_tokens(), 16);
    assert!(res_ar.t_llm_s > 0.0);

    let cfg = SessionConfig {
        policy: Policy::KSqs { k: 8 },
        temp: 0.3,
        max_new_tokens: 16,
        seed: 7,
        ..Default::default()
    };
    let mut sess = stack.session(LinkConfig::default(), cfg);
    let res_sd = sess.run(&prompt).unwrap();
    // SD must invoke the LLM strictly fewer times than AR generates tokens
    assert!(
        res_sd.batches.len() < res_ar.new_tokens(),
        "SD used {} LLM calls for {} tokens; AR used {}",
        res_sd.batches.len(), res_sd.new_tokens(), res_ar.new_tokens()
    );
}

#[test]
fn kv_pool_tracks_sessions() {
    let Some(stack) = stack_or_skip() else { return };
    assert_eq!(stack.slm.kv_pool.live_sessions(), 0);
    let cfg = SessionConfig { max_new_tokens: 4, ..Default::default() };
    {
        let mut sess = stack.session(LinkConfig::default(), cfg);
        sess.run(&encode("The weather report")).unwrap();
        assert_eq!(stack.slm.kv_pool.live_sessions(), 1);
        assert_eq!(stack.llm.kv_pool.live_sessions(), 1);
    }
    assert_eq!(stack.slm.kv_pool.live_sessions(), 0, "lease released on drop");
    assert!(stack.slm.kv_pool.total_allocs() >= 1);
}
