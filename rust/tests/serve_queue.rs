//! Pins on the extracted admission/coalescing core: the shared
//! [`VerifyQueue`] must behave bit-identically to the `CloudVerifier`
//! wrapper the fleet simulator keeps (same drain order, same counters,
//! same congestion/grant extensions), and the wire-server-only features
//! (bounded enqueue, metrics handles) must compose with it without
//! disturbing that arithmetic.

use sqs_sd::coordinator::{linear_bounds, log_bounds, Metrics};
use sqs_sd::fleet::{CloudVerifier, VerifierConfig};
use sqs_sd::protocol::Ext;
use sqs_sd::serve::{QueueConfig, QueueMetrics, VerifyQueue};

/// One shared shape for the equivalence drives below.
fn cfg() -> QueueConfig {
    QueueConfig {
        concurrency: 2,
        batch_max: 3,
        base_s: 4e-3,
        per_token_s: 1e-4,
        congestion_depth: 2,
        grant_pool_bits: Some(6000),
        grant_min_bits: 100,
        ..Default::default()
    }
}

#[test]
fn queue_matches_the_fleet_wrapper_step_for_step() {
    // `VerifierConfig` *is* `QueueConfig`: one knob set, two faces
    let mut fleet = CloudVerifier::new(cfg());
    let mut wire: VerifyQueue<usize> = VerifyQueue::new(cfg());

    for d in [3usize, 1, 4, 1, 5, 9, 2, 6] {
        fleet.enqueue(d);
        wire.enqueue(d, 0.0);
    }
    while fleet.slot_free() || wire.slot_free() {
        assert_eq!(fleet.slot_free(), wire.slot_free());
        let a = fleet.take_batch();
        let b = wire.take_batch(0.0);
        assert_eq!(a, b, "identical drain order and coalescing");
        let tokens = 16 * a.len();
        assert_eq!(fleet.service_s(tokens), wire.service_s(tokens));
        assert_eq!(fleet.feedback_exts(6), wire.feedback_exts(6));
        fleet.release_slot();
        wire.release_slot();
    }
    assert_eq!(fleet.calls, wire.calls);
    assert_eq!(fleet.windows, wire.windows);
    assert_eq!(fleet.busy_s, wire.busy_s);
    assert_eq!(fleet.peak_queue, wire.peak_queue);
    assert_eq!(fleet.mean_batch(), wire.mean_batch());
    assert_eq!(fleet.grant_round_max_bits, wire.grant_round_max_bits);
}

#[test]
fn grants_scale_with_backlog_on_both_faces() {
    let mut fleet = CloudVerifier::new(VerifierConfig {
        congestion_depth: 2,
        grant_pool_bits: Some(6000),
        grant_min_bits: 100,
        ..Default::default()
    });
    let mut wire: VerifyQueue<usize> = VerifyQueue::new(QueueConfig {
        congestion_depth: 2,
        grant_pool_bits: Some(6000),
        grant_min_bits: 100,
        ..Default::default()
    });
    for d in 0..4 {
        fleet.enqueue(d);
        wire.enqueue(d, 0.0);
    }
    // backlog 4 > depth 2: the fair share is scaled by 2/4 on BOTH
    // paths — the threaded server used to skip this scaling (scale 1.0)
    assert_eq!(fleet.grant_for(6), Some(500));
    assert_eq!(wire.grant_for(6), Some(500));
    let exts = wire.feedback_exts(6);
    assert!(exts.contains(&Ext::Congestion(true)));
    assert!(exts.contains(&Ext::BudgetGrant(500)));
    // the conservation diagnostic records grant * live at each emission
    assert!(wire.grant_round_max_bits <= 6000);
    assert!(wire.grant_round_max_bits >= 500 * 6);
}

#[test]
fn bounded_enqueue_refuses_backpressure_not_loss() {
    let mut q: VerifyQueue<usize> =
        VerifyQueue::new(QueueConfig { max_backlog: 2, ..Default::default() });
    assert!(q.try_enqueue(7, 0.0).is_ok());
    assert!(q.try_enqueue(8, 0.1).is_ok());
    // full: the item comes back to the caller (who keeps it queued in
    // the session FIFO), and the pressure event is counted
    assert_eq!(q.try_enqueue(9, 0.2), Err(9));
    assert_eq!(q.refused, 1);
    assert_eq!(q.backlog(), 2);
    // draining makes room again
    let batch = q.take_batch(0.3);
    assert_eq!(batch, vec![7, 8]);
    q.release_slot();
    assert!(q.try_enqueue(9, 0.4).is_ok());
    assert_eq!(q.take_batch(0.5), vec![9]);

    // max_backlog 0 never refuses (the fleet path's unconditional mode)
    let mut open: VerifyQueue<usize> = VerifyQueue::new(QueueConfig::default());
    for d in 0..100 {
        assert!(open.try_enqueue(d, 0.0).is_ok());
    }
    assert_eq!(open.refused, 0);
}

#[test]
fn metrics_handles_observe_batch_sizes_and_queue_waits() {
    let metrics = Metrics::new();
    let mut q: VerifyQueue<usize> =
        VerifyQueue::new(QueueConfig { batch_max: 4, ..Default::default() });
    q.set_metrics(QueueMetrics {
        batch_size: metrics.histogram_handle("verify.batch_size", &linear_bounds(0.0, 32.0, 32)),
        queue_wait: metrics.histogram_handle("verify.queue_wait", &log_bounds(1e-6, 10.0, 6)),
    });
    q.enqueue(1, 0.0);
    q.enqueue(2, 0.25);
    assert_eq!(q.take_batch(0.5), vec![1, 2]);

    let bs = metrics.histogram("verify.batch_size").expect("registered");
    assert_eq!(bs.count(), 1, "one coalesced call");
    assert_eq!(bs.sum(), 2.0, "two windows in it");
    let qw = metrics.histogram("verify.queue_wait").expect("registered");
    assert_eq!(qw.count(), 2, "one wait sample per window");
    assert!((qw.sum() - 0.75).abs() < 1e-12, "0.5s + 0.25s of waiting: {}", qw.sum());

    // an empty take observes nothing (no zero-size batch samples)
    q.release_slot();
    assert!(q.take_batch(1.0).is_empty());
    assert_eq!(bs.count(), 1);
}
