//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! These are the L1/L2/L3 cross-checks: the rust SLQ vs the Pallas kernel
//! through HLO, model generation quality, and KV-cache coherence through
//! the prefill/decode/verify serving phases.

#![cfg(feature = "pjrt")]

use std::sync::Arc;

use sqs_sd::model::lm::{ModelAssets, PjrtDraft, PjrtTarget};
use sqs_sd::model::{encode, DraftLm, TargetLm};
use sqs_sd::runtime::{
    lit_f32, lit_i32, lit_scalar_f32, lit_scalar_i32, lit_to_i32, Arg, Engine, Manifest,
};
use sqs_sd::sqs::probs::softmax_t;
use sqs_sd::sqs::{sparse_quantize, Sparsifier};
use sqs_sd::util::rng::Pcg64;

fn manifest_or_skip() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

/// NOTE: PjRtClient is Rc-based (not Send) and the CPU plugin crashes when
/// clients are created/destroyed concurrently on different threads — these
/// tests MUST run with `--test-threads=1` (the Makefile does).
fn engine() -> Arc<Engine> {
    Arc::new(Engine::cpu().expect("PJRT CPU client"))
}

const KV_BUDGET: u64 = 1 << 30;

#[test]
fn sqs_kernel_hlo_matches_rust_slq() {
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let art = manifest.artifact("sqs_kernel").unwrap();
    let module = eng.load_module(&art.file).unwrap();

    let mut rng = Pcg64::new(2024, 0);
    for case in 0..40 {
        // random f32 probability vector exactly as the kernel would see it
        let sharp = 0.3 + 5.0 * rng.next_f64();
        let logits: Vec<f32> = (0..256).map(|_| (rng.normal() * sharp) as f32).collect();
        let q = softmax_t(&logits, 1.0);
        let (mode, param, ell) = if case % 2 == 0 {
            (0i32, (1 + rng.below(64)) as f32, 100u32)
        } else {
            (1i32, rng.next_f32() * 0.05, 100u32)
        };

        let q_lit = xla::Literal::vec1(&q);
        let mode_l = lit_i32(mode);
        let param_l = lit_f32(param);
        let ell_l = lit_i32(ell as i32);
        let out = module
            .call(&eng, &[Arg::Host(&q_lit), Arg::Host(&mode_l),
                          Arg::Host(&param_l), Arg::Host(&ell_l)])
            .unwrap();
        assert_eq!(out.len(), 3, "counts, alpha, kept");
        let counts_hlo = lit_to_i32(&out[0]).unwrap();
        let alpha_hlo = lit_scalar_f32(&out[1]).unwrap();
        let kept_hlo = lit_scalar_i32(&out[2]).unwrap() as usize;

        let sp = if mode == 0 {
            Sparsifier::top_k(param as usize)
        } else {
            Sparsifier::threshold(param)
        };
        let z = sparse_quantize(&q, &sp, ell);
        assert_eq!(z.k(), kept_hlo, "case {case}: support size");
        let dense = z.to_dense_counts(256);
        for i in 0..256 {
            assert_eq!(
                dense[i] as i32, counts_hlo[i],
                "case {case}: count mismatch at token {i} (mode={mode} param={param})"
            );
        }
        assert!(
            (z.alpha - alpha_hlo).abs() < 1e-6,
            "case {case}: alpha {} vs {}", z.alpha, alpha_hlo
        );
    }
}

#[test]
fn slm_draft_loop_runs_and_is_coherent() {
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let assets = ModelAssets::load(eng, &manifest, "slm", KV_BUDGET).unwrap();
    let mut draft = PjrtDraft::new(assets);
    let prompt = encode("The capital of France is");
    draft.start(&prompt).unwrap();

    let sp = Sparsifier::top_k(8);
    let mut rng = Pcg64::new(7, 7);
    let mut text = Vec::new();
    for _ in 0..12 {
        let step = draft.next_sqs(0.7, &sp, 100).unwrap();
        assert_eq!(step.quant.counts.iter().sum::<u32>(), 100);
        assert_eq!(step.probs.len(), 256);
        let s: f32 = step.probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "probs normalized, got {s}");
        let tok = sqs_sd::sqs::probs::sample_lattice(&step.quant.to_dense_counts(256), 100, &mut rng);
        draft.commit(tok as u16).unwrap();
        text.push(tok as u16);
    }
    assert_eq!(draft.len(), prompt.len() + 12);
    // trained on the corpus: drafted bytes should be printable ASCII mostly
    let printable = text.iter().filter(|&&t| (32..127).contains(&t)).count();
    assert!(printable >= 9, "draft produced {printable}/12 printable bytes: {text:?}");
}

#[test]
fn greedy_completion_reproduces_corpus_fact() {
    // The LLM memorized the tiny corpus; greedy decoding after the prompt
    // "The capital of France is" must produce " Paris" — the paper's own
    // motivating example for aggressive sparsification.
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let assets = ModelAssets::load(eng, &manifest, "llm", KV_BUDGET).unwrap();
    let mut tgt = PjrtTarget::new(assets);
    let prompt = encode("The capital of France is");
    tgt.start(&prompt).unwrap();
    let mut out = Vec::new();
    for _ in 0..6 {
        let p = tgt.decode_probs(0.01).unwrap();
        let tok = p
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u16;
        tgt.commit_tokens(&[tok]).unwrap();
        out.push(tok);
    }
    let s = sqs_sd::model::decode(&out);
    assert_eq!(s, " Paris", "greedy completion was {s:?}");
}

#[test]
fn verify_window_consistent_with_decode() {
    // p from verify_window must match p from step-by-step decode_probs on
    // the same committed context — the cache-coherence contract.
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let assets = ModelAssets::load(eng.clone(), &manifest, "llm", KV_BUDGET).unwrap();

    let prompt = encode("Once there was a fox who");
    let drafts = encode(" lived at");
    let temp = 0.8f32;

    // path A: verify window over the drafts
    let mut a = PjrtTarget::new(assets.clone());
    a.start(&prompt).unwrap();
    let mut window = vec![*prompt.last().unwrap()];
    window.extend_from_slice(&drafts);
    let probs_window = a.verify_window(&window, temp).unwrap();

    // path B: commit + decode token by token
    let mut b = PjrtTarget::new(assets);
    b.start(&prompt).unwrap();
    let mut ctx = prompt.clone();
    for (i, &d) in drafts.iter().enumerate() {
        let p_b = b.decode_probs(temp).unwrap();
        let p_a = &probs_window[i];
        let max_diff = p_a
            .iter()
            .zip(&p_b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-3, "position {i}: verify/decode diverge by {max_diff}");
        ctx.push(d);
        b.commit_tokens(&[d]).unwrap();
    }
}

#[test]
fn commit_without_decode_catches_up() {
    // Regression: in an all-accepted speculative batch the last draft and
    // the cloud's bonus token are committed without ever being decoded,
    // leaving unwritten KV rows.  next_sqs must catch up (raw-decode the
    // gap) or every subsequent draft is conditioned on garbage.
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let assets = ModelAssets::load(eng, &manifest, "slm", KV_BUDGET).unwrap();
    let sp = Sparsifier::top_k(1);
    let prompt = encode("The capital of Italy is");
    let extra = encode(" Rome.");

    // session A: commit the continuation in one go (the gap case)
    let mut a = PjrtDraft::new(assets.clone());
    a.start(&prompt).unwrap();
    for &t in &extra {
        a.commit(t).unwrap();
    }
    let qa = a.next_sqs(0.5, &sp, 100).unwrap();

    // session B: the same context via prefill (ground truth)
    let mut b = PjrtDraft::new(assets);
    let mut full = prompt.clone();
    full.extend_from_slice(&extra);
    b.start(&full).unwrap();
    let qb = b.next_sqs(0.5, &sp, 100).unwrap();

    let max_diff = qa
        .probs
        .iter()
        .zip(&qb.probs)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "gap-committed context diverges: {max_diff}");
    assert_eq!(qa.quant.support, qb.quant.support);
}

#[test]
fn draft_rollback_reproduces_fresh_context() {
    // Draft 5 tokens, roll back to the prompt, re-draft deterministically
    // (top-1) — results must match a fresh session (KV overwrite contract).
    let Some(manifest) = manifest_or_skip() else { return };
    let eng = engine();
    let assets = ModelAssets::load(eng, &manifest, "slm", KV_BUDGET).unwrap();
    let sp = Sparsifier::top_k(1);

    let prompt = encode("To make the bread, first");
    let mut d1 = PjrtDraft::new(assets.clone());
    d1.start(&prompt).unwrap();
    // pollute the cache beyond the prompt
    for _ in 0..5 {
        let step = d1.next_sqs(1.0, &Sparsifier::top_k(4), 100).unwrap();
        // commit the *least* likely of the top-4 to force divergence
        let tok = *step.quant.support.last().unwrap();
        d1.commit(tok).unwrap();
    }
    d1.rollback(prompt.len()).unwrap();
    let mut seq1 = Vec::new();
    for _ in 0..5 {
        let step = d1.next_sqs(0.01, &sp, 100).unwrap();
        let tok = step.quant.support[0];
        d1.commit(tok).unwrap();
        seq1.push(tok);
    }

    let mut d2 = PjrtDraft::new(assets);
    d2.start(&prompt).unwrap();
    let mut seq2 = Vec::new();
    for _ in 0..5 {
        let step = d2.next_sqs(0.01, &sp, 100).unwrap();
        let tok = step.quant.support[0];
        d2.commit(tok).unwrap();
        seq2.push(tok);
    }
    assert_eq!(seq1, seq2, "rollback session diverged from fresh session");
}
