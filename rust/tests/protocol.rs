//! Protocol-correctness tests over the synthetic backend (no artifacts
//! needed): the speculative-decoding + QS guarantee, conformal behaviour,
//! budget/ledger invariants of the full session loop, and the protocol-v2
//! wire layer (handshake accounting, v1 layout compatibility, and
//! fuzz-style corruption of every frame type).

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::session::{SdSession, SessionConfig, TimingMode};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::protocol::{
    Control, Ext, FeedbackV2, Frame, Hello, SeqAck, SeqDraft, TreeAck, TreeDraft, WireCodec,
    FRAME_HEADER_BITS, HELLO_ACK_BITS, HELLO_BITS, MAX_SUPPORTED, MIN_SUPPORTED, NO_PARENT,
    PROTOCOL_V3, PROTOCOL_V4,
};
use sqs_sd::sqs::bits::SchemeBits;
use sqs_sd::sqs::Policy;
use sqs_sd::util::check::check;
use sqs_sd::util::stats::tv_distance;

fn modeled() -> TimingMode {
    TimingMode::Modeled { slm_step_s: 1e-4, llm_call_s: 1e-3 }
}

fn make_session(world: &SyntheticWorld, policy: Policy, temp: f32, seed: u64,
                max_new: usize) -> SdSession<SyntheticDraft, SyntheticTarget> {
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
    let link = SimulatedLink::new(LinkConfig::default(), seed);
    let cfg = SessionConfig {
        policy,
        temp,
        max_new_tokens: max_new,
        seed,
        timing: modeled(),
        ..Default::default()
    };
    SdSession::new(draft, target, link, cfg)
}

/// THE speculative-decoding guarantee: accepted+resampled tokens follow the
/// target distribution exactly, *even with aggressive sparsification* —
/// QS samples drafts from q_hat and verifies against q_hat.
///
/// The synthetic world is Markov (distribution depends only on the previous
/// token), so the first generated token after prompt [s] across many seeded
/// sessions must be distributed as p(. | s).
#[test]
fn sd_outputs_follow_target_distribution() {
    let world = SyntheticWorld::new(32, 0.8, 99);
    let temp = 0.9f32;
    let prev = 5u16;
    let p_ref = world.target_probs(prev, temp);

    for policy in [
        Policy::KSqs { k: 4 },
        Policy::CSqs { beta0: 0.02, alpha: 0.02, eta: 0.05 },
        Policy::DenseQs,
    ] {
        let n = 30_000usize;
        let mut freq = vec![0u64; 32];
        for seed in 0..n {
            let mut sess = make_session(&world, policy, temp, seed as u64, 1);
            let res = sess.run(&[prev]).unwrap();
            let first = res.tokens[1];
            freq[first as usize] += 1;
        }
        let emp: Vec<f32> = freq.iter().map(|&c| c as f32 / n as f32).collect();
        let tv = tv_distance(&emp, &p_ref);
        // TV of an n-sample empirical distribution over 32 outcomes
        // concentrates near sqrt(V/(2*pi*n)) ~ 0.013; 0.03 is ~3 sigma.
        assert!(
            tv < 0.03,
            "{}: empirical TV {tv:.4} too far from target (SD guarantee broken?)",
            policy.name()
        );
    }
}

/// Acceptance must degrade as sparsification gets more aggressive (smaller
/// K drops more target mass), and dense QS must accept the most.
#[test]
fn acceptance_monotone_in_k() {
    let world = SyntheticWorld::new(64, 0.6, 3);
    let mut rates = Vec::new();
    for k in [1usize, 2, 8, 64] {
        let mut sess = make_session(&world, Policy::KSqs { k }, 1.0, 7, 400);
        let res = sess.run(&[9, 3, 1]).unwrap();
        rates.push(res.acceptance_rate());
    }
    for w in rates.windows(2) {
        assert!(
            w[1] >= w[0] - 0.03,
            "acceptance should not degrade with larger K: {rates:?}"
        );
    }
    assert!(rates[3] > rates[0] + 0.05, "K=64 must beat K=1 clearly: {rates:?}");
}

/// Per-batch distribution payload must respect the budget B (§4).
#[test]
fn budget_respected_for_all_policies() {
    let world = SyntheticWorld::new(64, 0.5, 1);
    for policy in [
        Policy::KSqs { k: 8 },
        Policy::CSqs { beta0: 0.01, alpha: 0.005, eta: 0.01 },
        Policy::DenseQs,
    ] {
        let mut sess = make_session(&world, policy, 0.9, 11, 200);
        let res = sess.run(&[1]).unwrap();
        for (i, b) in res.batches.iter().enumerate() {
            assert!(
                b.dist_bits <= 5000 || b.drafted == 1,
                "{} batch {i}: {} bits > B=5000 with {} drafts",
                policy.name(), b.dist_bits, b.drafted
            );
        }
    }
}

/// Theorem 2 certificate on the real protocol (not the synthetic-alpha
/// stream of the unit test): empirical mean dropped mass <= bound.
#[test]
fn theorem2_holds_in_protocol() {
    let world = SyntheticWorld::new(64, 0.7, 21);
    for (eta, alpha, beta0) in [
        (0.001f64, 0.0005f64, 0.01f64),   // the paper's operating point
        (0.01, 0.01, 0.05),
        (0.1, 0.05, 0.5),
    ] {
        let mut sess = make_session(
            &world,
            Policy::CSqs { beta0, alpha, eta },
            1.0,
            5,
            600,
        );
        let res = sess.run(&[2, 4]).unwrap();
        let emp = res.conformal_empirical_alpha.unwrap();
        let bound = res.conformal_bound.unwrap();
        assert!(
            emp <= bound + 1e-9,
            "eta={eta} alpha={alpha}: empirical {emp} > bound {bound}"
        );
        assert!(res.conformal_t.unwrap() > 0);
    }
}

/// eta = 0 disables adaptation: the threshold never moves, and the
/// Theorem 2 certificate degenerates (infinite bound).
#[test]
fn eta_zero_no_adaptation() {
    let world = SyntheticWorld::new(64, 0.7, 5);
    let mut sess = make_session(
        &world,
        Policy::CSqs { beta0: 0.02, alpha: 0.0005, eta: 0.0 },
        1.0,
        5,
        100,
    );
    let res = sess.run(&[2]).unwrap();
    assert!(res.conformal_bound.unwrap().is_infinite());
    let beta = sess.edge.conformal.as_ref().unwrap().beta();
    assert_eq!(beta, 0.02, "eta=0 must never move the threshold");
}

/// The latency ledger must be internally consistent and each component
/// must match its model (handshake frames included since protocol v2).
#[test]
fn latency_ledger_consistent() {
    let world = SyntheticWorld::new(64, 0.5, 13);
    let mut sess = make_session(&world, Policy::KSqs { k: 8 }, 0.8, 3, 64);
    let res = sess.run(&[1, 2, 3]).unwrap();
    let sum = res.t_slm_s + res.t_uplink_s + res.t_llm_s + res.t_downlink_s;
    assert!((res.total_time_s - sum).abs() < 1e-12);
    // modeled compute: slm time = 1e-4 * total drafted
    let drafted: usize = res.batches.iter().map(|b| b.drafted).sum();
    assert!((res.t_slm_s - 1e-4 * drafted as f64).abs() < 1e-9);
    assert!((res.t_llm_s - 1e-3 * res.batches.len() as f64).abs() < 1e-9);
    // uplink time from the deterministic link formula: the Hello frame
    // plus one draft frame per batch
    let expect_up: f64 = res.handshake_uplink_bits as f64 / 1e6
        + 0.010
        + res
            .batches
            .iter()
            .map(|b| b.frame_bits as f64 / 1e6 + 0.010)
            .sum::<f64>();
    assert!((res.t_uplink_s - expect_up).abs() < 1e-9, "{} vs {expect_up}", res.t_uplink_s);
    // downlink likewise: HelloAck + one feedback frame per batch
    let expect_down: f64 = res.handshake_downlink_bits as f64 / 1e7
        + 0.010
        + res
            .batches
            .iter()
            .map(|b| b.feedback_bits as f64 / 1e7 + 0.010)
            .sum::<f64>();
    assert!((res.t_downlink_s - expect_down).abs() < 1e-9, "{} vs {expect_down}", res.t_downlink_s);
    let rr = res.resampling_rate();
    assert!((0.0..=1.0).contains(&rr));
    assert_eq!(res.n_rej, res.batches.iter().filter(|b| b.rejected).count());
}

/// Wire-bit ledger exactness: every bit in `uplink_bits`/`downlink_bits`
/// is attributable — handshake frames plus per-batch frames, nothing
/// else — and the v2 draft frame costs exactly the 8-bit header over the
/// v1 layout (header 40 + payloads), keeping b_n accounting intact.
#[test]
fn wire_ledger_exact_with_handshake() {
    let world = SyntheticWorld::new(64, 0.5, 23);
    let mut sess = make_session(&world, Policy::KSqs { k: 8 }, 0.9, 4, 48);
    let res = sess.run(&[2, 7]).unwrap();

    assert_eq!(res.handshake_uplink_bits, HELLO_BITS as u64);
    assert_eq!(res.handshake_downlink_bits, HELLO_ACK_BITS as u64);
    let batch_up: u64 = res.batches.iter().map(|b| b.frame_bits as u64).sum();
    let batch_down: u64 = res.batches.iter().map(|b| b.feedback_bits as u64).sum();
    assert_eq!(res.uplink_bits, res.handshake_uplink_bits + batch_up);
    assert_eq!(res.downlink_bits, res.handshake_downlink_bits + batch_down);

    for b in &res.batches {
        // v2 header (8) + v1 frame header (32 id + 8 count) + payloads:
        // dist bits + ceil(log2 V) = 6 bits per sampled token at V=64
        assert_eq!(
            b.frame_bits,
            FRAME_HEADER_BITS + 40 + b.dist_bits + 6 * b.drafted,
            "draft frame bits must decompose exactly"
        );
        // plain v2 feedback: header + v1 core (64) + empty ext count (4)
        assert_eq!(b.feedback_bits, FRAME_HEADER_BITS + 68);
        // knob trace rides every batch
        assert_eq!(b.knobs.ell, 15);
        assert_eq!(b.knobs.budget_bits, 5000);
    }
}

/// Determinism: same seed, same trajectory; different seed diverges.
#[test]
fn deterministic_given_seed() {
    let world = SyntheticWorld::new(64, 0.5, 17);
    let run = |seed: u64| {
        let mut sess = make_session(
            &world,
            Policy::CSqs { beta0: 0.01, alpha: 0.001, eta: 0.01 },
            0.9,
            seed,
            50,
        );
        sess.run(&[4, 4]).unwrap().tokens
    };
    assert_eq!(run(123), run(123), "same seed, same trajectory");
    assert_ne!(run(123), run(124), "different seed should diverge");
}

/// With draft == target (mismatch 0) and a fine lattice, rejections are
/// bounded by the quantization distortion alone (Theorem 1 with zero
/// discrepancy term).
#[test]
fn identical_models_almost_never_reject() {
    let world = SyntheticWorld::new(32, 0.0, 9);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
    let link = SimulatedLink::new(LinkConfig::default(), 5);
    let cfg = SessionConfig {
        policy: Policy::DenseQs,
        temp: 1.0,
        ell: 4000, // fine lattice: V/(4*ell) = 0.002
        max_new_tokens: 300,
        seed: 5,
        timing: modeled(),
        ..Default::default()
    };
    let mut sess = SdSession::new(draft, target, link, cfg);
    let res = sess.run(&[8]).unwrap();
    assert!(
        res.resampling_rate() < 0.05,
        "identical models + fine lattice must almost never reject: rate={}",
        res.resampling_rate()
    );
}

/// Theorem 1 shape: the resampling rate should increase with draft–target
/// mismatch (the SLM–LLM discrepancy term).
#[test]
fn resampling_grows_with_mismatch() {
    let mut rates = Vec::new();
    for mismatch in [0.0, 0.5, 2.0] {
        let world = SyntheticWorld::new(64, mismatch, 31);
        let mut sess = make_session(&world, Policy::DenseQs, 1.0, 2, 400);
        let res = sess.run(&[3]).unwrap();
        rates.push(res.resampling_rate());
    }
    assert!(
        rates[2] > rates[0] + 0.1,
        "mismatch 2.0 must reject far more than 0.0: {rates:?}"
    );
}

// ---------------------------------------------------------------------
// protocol v2 wire layer
// ---------------------------------------------------------------------

/// Build one of each frame type for corruption / roundtrip tests.
fn sample_frames(codec: &mut WireCodec) -> Vec<(&'static str, Vec<u8>)> {
    use sqs_sd::codec::{DraftFrame, DraftToken};
    use sqs_sd::sqs::{sparse_quantize, Sparsifier};

    let mut g = sqs_sd::util::check::Gen { rng: sqs_sd::util::rng::Pcg64::new(404, 0) };
    let tokens: Vec<DraftToken> = (0..3)
        .map(|_| {
            let q = g.probs(64, 2.0);
            let quant = sparse_quantize(&q, &Sparsifier::top_k(8), 100);
            let token = quant.support[0];
            DraftToken { quant, token }
        })
        .collect();
    let frames = vec![
        Frame::Hello(Hello {
            min_version: MIN_SUPPORTED,
            max_version: MAX_SUPPORTED,
            vocab: 64,
            ell: 100,
            scheme: SchemeBits::FixedK,
            fixed_k: 8,
            resume_token: 0,
        }),
        Frame::HelloAck(sqs_sd::protocol::negotiate(&Hello {
            min_version: MIN_SUPPORTED,
            max_version: MAX_SUPPORTED,
            vocab: 64,
            ell: 100,
            scheme: SchemeBits::FixedK,
            fixed_k: 8,
            resume_token: 0,
        })
        .unwrap()),
        Frame::Draft(DraftFrame { batch_id: 77, tokens: tokens.clone() }),
        Frame::DraftSeq(SeqDraft {
            seq: u16::MAX, // wraparound corner on the wire
            epoch: u8::MAX,
            frame: DraftFrame { batch_id: 78, tokens: tokens.clone() },
        }),
        Frame::DraftTree(TreeDraft {
            seq: u16::MAX,
            epoch: u8::MAX,
            // trunk 0-1 plus a root sibling: the smallest non-chain tree
            parents: vec![NO_PARENT, 0, NO_PARENT],
            frame: DraftFrame { batch_id: 79, tokens },
        }),
        Frame::Feedback(FeedbackV2 {
            batch_id: 9,
            accepted: 2,
            new_token: 40,
            exts: vec![
                Ext::Congestion(true),
                Ext::BudgetGrant(600),
                Ext::Ack(SeqAck { seq: u16::MAX, epoch: 3, discard: false }),
            ],
        }),
        Frame::Feedback(FeedbackV2 {
            batch_id: 11,
            accepted: 2,
            new_token: 40,
            exts: vec![Ext::TreeAck(TreeAck {
                seq: u16::MAX,
                epoch: u8::MAX,
                discard: false,
                resampled: true,
                node: 2,
                depth: 2,
            })],
        }),
        Frame::Feedback(FeedbackV2::discard(10, 0, u8::MAX)),
        Frame::Control(Control::Prompt(vec![1, 2, 3])),
        Frame::Control(Control::Bye),
    ];
    frames
        .into_iter()
        .map(|f| {
            let name = f.name();
            let (bytes, _bits) = codec.encode(&f).unwrap();
            (name, bytes)
        })
        .collect()
}

/// Corruption fuzz: for EVERY frame type, (a) all byte truncations of a
/// valid encoding must decode to `Err` — never panic — and (b) random
/// bit flips must never panic (they may decode to garbage `Ok`, which
/// the verify layer rejects downstream).
#[test]
fn corrupted_v2_frames_error_never_panic() {
    // a v4 codec decodes every frame type, sequenced drafts and trees
    // included
    let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    codec.set_version(PROTOCOL_V4);
    let frames = sample_frames(&mut codec);

    for (name, bytes) in &frames {
        // (a) every strict prefix loses payload bits -> must Err
        for cut in 0..bytes.len() {
            let r = codec.decode(&bytes[..cut]);
            assert!(r.is_err(), "{name}: truncation to {cut}/{} bytes must fail", bytes.len());
        }
    }

    // (b) seeded bit-flip storm over every frame type; util/check catches
    // panics and reports the reproducing (seed, case).  For tree frames
    // this storm also lands flips in the parent-pointer table, so
    // out-of-range pointers must come back as Err, never a panic.
    check("v2 frame corruption never panics", 300, |g, _| {
        let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
        codec.set_version(PROTOCOL_V4);
        let frames = sample_frames(&mut codec);
        let (name, bytes) = g.pick(&frames);
        let mut corrupt = bytes.clone();
        let flips = g.usize(1, 16);
        for _ in 0..flips {
            let bit = g.usize(0, corrupt.len() * 8 - 1);
            corrupt[bit / 8] ^= 1 << (bit % 8);
        }
        // decoding must terminate without panicking; Ok(garbage) is fine
        let _ = codec.decode(&corrupt);
        let _ = name;
    });

    // (c) down-version codecs must refuse newer frames outright — never
    // panic, never misparse them as something else
    let mut v2 = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    let mut v3 = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    v3.set_version(PROTOCOL_V3);
    for (name, bytes) in &frames {
        if *name == "draft_seq" {
            assert!(v2.decode(bytes).is_err(), "v2 codec must reject sequenced drafts");
        }
        if *name == "draft_tree" {
            assert!(v2.decode(bytes).is_err(), "v2 codec must reject draft trees");
            assert!(v3.decode(bytes).is_err(), "v3 codec must reject draft trees");
        }
    }

    // (d) every parent byte of a valid tree forced out of range must Err
    let (_, tree_bytes) = frames
        .iter()
        .find(|(n, _)| *n == "draft_tree")
        .expect("sample set includes a tree");
    // layout: header(8) seq(16) epoch(8) n(8) then one parent byte/node
    for node in 0..3usize {
        let mut corrupt = tree_bytes.clone();
        corrupt[5 + node] = 0x80 | node as u8; // >= node index, not 0xFF
        assert!(
            codec.decode(&corrupt).is_err(),
            "node {node}: out-of-range parent must Err"
        );
    }
}

/// Sequence/epoch wraparound and stale/duplicate feedback never panic
/// the codec layer: any (seq, epoch, discard) triple round-trips, and
/// re-decoding the same feedback frame twice is harmless (the session
/// layer is what rejects duplicates, by popping its in-flight ledger).
#[test]
fn seq_ack_wraparound_roundtrips_for_any_triple() {
    check("seq ack wraparound", 200, |g, _| {
        let seq = g.int(0, u16::MAX as u64) as u16;
        let epoch = g.int(0, u8::MAX as u64) as u8;
        let discard = g.bool();
        let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
        codec.set_version(PROTOCOL_V3);
        let fb = FeedbackV2 {
            batch_id: 1,
            accepted: 0,
            new_token: 0,
            exts: vec![Ext::Ack(SeqAck { seq, epoch, discard })],
        };
        let (bytes, _) = codec.encode(&Frame::Feedback(fb.clone())).unwrap();
        for _ in 0..2 {
            // decoding twice = a duplicated feedback frame on the wire
            match codec.decode(&bytes).unwrap() {
                Frame::Feedback(back) => {
                    assert_eq!(back, fb);
                    assert_eq!(back.ack(), Some(SeqAck { seq, epoch, discard }));
                }
                other => panic!("expected feedback, got {}", other.name()),
            }
        }
    });
}

// ---------------------------------------------------------------------
// borrowed decoder paths (decode_view / WireArena)
// ---------------------------------------------------------------------

/// The borrowed decoder must agree with the owned decoder on EVERY frame
/// type, through a single reused arena — `decode_view(..).to_frame()`
/// pinned equal to `decode(..)`, twice over (arena-reuse soundness).
#[test]
fn view_decode_pinned_equal_to_owned_for_all_frame_types() {
    use sqs_sd::protocol::WireArena;

    let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    codec.set_version(PROTOCOL_V4);
    let frames = sample_frames(&mut codec);
    let mut arena = WireArena::new();
    for pass in 0..2 {
        for (name, bytes) in &frames {
            let owned = codec.decode(bytes).unwrap();
            let view = codec.decode_view(bytes, &mut arena).unwrap();
            assert_eq!(view.name(), *name, "pass {pass}");
            assert_eq!(
                view.to_frame(),
                owned,
                "{name} pass {pass}: view decode must equal owned decode"
            );
        }
    }
}

/// Corruption fuzz over the borrowed path, mirroring
/// `corrupted_v2_frames_error_never_panic`: (a) every truncation Errs,
/// (b) bit-flip storms (which also land in DraftTree parent bytes) never
/// panic, and wherever both decoders accept, they agree; (c) forced
/// out-of-range tree parents Err through the view path too.
#[test]
fn corrupted_frames_through_view_decoder_error_never_panic() {
    use sqs_sd::protocol::WireArena;

    let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
    codec.set_version(PROTOCOL_V4);
    let frames = sample_frames(&mut codec);
    let mut arena = WireArena::new();

    // (a) every strict prefix loses payload bits -> must Err, and the
    // arena must remain usable for the next decode afterwards
    for (name, bytes) in &frames {
        for cut in 0..bytes.len() {
            assert!(
                codec.decode_view(&bytes[..cut], &mut arena).is_err(),
                "{name}: view truncation to {cut}/{} bytes must fail",
                bytes.len()
            );
        }
        let view = codec.decode_view(bytes, &mut arena).unwrap();
        assert_eq!(view.name(), *name, "arena must survive failed decodes");
    }

    // (b) seeded bit-flip storm: the view decoder must terminate without
    // panicking, and on Ok both decoders must produce the same frame
    // (garbage in, *identical* garbage out)
    check("view decode corruption never panics", 300, |g, _| {
        let mut codec = WireCodec::for_config(64, 100, SchemeBits::FixedK, 8);
        codec.set_version(PROTOCOL_V4);
        let frames = sample_frames(&mut codec);
        let mut arena = WireArena::new();
        let (_, bytes) = g.pick(&frames);
        let mut corrupt = bytes.clone();
        let flips = g.usize(1, 16);
        for _ in 0..flips {
            let bit = g.usize(0, corrupt.len() * 8 - 1);
            corrupt[bit / 8] ^= 1 << (bit % 8);
        }
        let owned = codec.decode(&corrupt);
        let viewed = codec.decode_view(&corrupt, &mut arena);
        match (owned, viewed) {
            (Ok(o), Ok(v)) => assert_eq!(o, v.to_frame(), "decoders disagree on Ok"),
            (Err(_), Err(_)) => {}
            (o, v) => panic!(
                "decoders disagree on acceptance: owned {:?} vs view {:?}",
                o.is_ok(),
                v.is_ok()
            ),
        }
    });

    // (c) forced out-of-range parent bytes in a valid tree encoding
    let (_, tree_bytes) = frames
        .iter()
        .find(|(n, _)| *n == "draft_tree")
        .expect("sample set includes a tree");
    for node in 0..3usize {
        let mut corrupt = tree_bytes.clone();
        corrupt[5 + node] = 0x80 | node as u8; // >= node index, not 0xFF
        assert!(
            codec.decode_view(&corrupt, &mut arena).is_err(),
            "node {node}: out-of-range parent must Err through the view path"
        );
    }
}

/// The session-level handshake: a v2 session over the simulated link
/// negotiates, and the negotiated parameters round-trip the codec config.
#[test]
fn session_handshake_negotiates_and_bits_are_ledgered() {
    let world = SyntheticWorld::new(64, 0.5, 3);
    for policy in [
        Policy::KSqs { k: 8 },
        Policy::CSqs { beta0: 0.01, alpha: 0.001, eta: 0.01 },
        Policy::DenseQs,
    ] {
        let mut sess = make_session(&world, policy, 0.9, 1, 8);
        let res = sess.run(&[5]).unwrap();
        assert_eq!(res.handshake_uplink_bits, HELLO_BITS as u64, "{}", policy.name());
        assert_eq!(res.handshake_downlink_bits, HELLO_ACK_BITS as u64);
        assert!(res.uplink_bits > res.handshake_uplink_bits);
    }
}
