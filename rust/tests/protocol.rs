//! Protocol-correctness tests over the synthetic backend (no artifacts
//! needed): the speculative-decoding + QS guarantee, conformal behaviour,
//! and budget/ledger invariants of the full session loop.

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::session::{SdSession, SessionConfig, TimingMode};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;
use sqs_sd::util::stats::tv_distance;

fn modeled() -> TimingMode {
    TimingMode::Modeled { slm_step_s: 1e-4, llm_call_s: 1e-3 }
}

fn make_session(world: &SyntheticWorld, policy: Policy, temp: f32, seed: u64,
                max_new: usize) -> SdSession<SyntheticDraft, SyntheticTarget> {
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
    let link = SimulatedLink::new(LinkConfig::default(), seed);
    let cfg = SessionConfig {
        policy,
        temp,
        max_new_tokens: max_new,
        seed,
        timing: modeled(),
        ..Default::default()
    };
    SdSession::new(draft, target, link, cfg)
}

/// THE speculative-decoding guarantee: accepted+resampled tokens follow the
/// target distribution exactly, *even with aggressive sparsification* —
/// QS samples drafts from q_hat and verifies against q_hat.
///
/// The synthetic world is Markov (distribution depends only on the previous
/// token), so the first generated token after prompt [s] across many seeded
/// sessions must be distributed as p(. | s).
#[test]
fn sd_outputs_follow_target_distribution() {
    let world = SyntheticWorld::new(32, 0.8, 99);
    let temp = 0.9f32;
    let prev = 5u16;
    let p_ref = world.target_probs(prev, temp);

    for policy in [
        Policy::KSqs { k: 4 },
        Policy::CSqs { beta0: 0.02, alpha: 0.02, eta: 0.05 },
        Policy::DenseQs,
    ] {
        let n = 30_000usize;
        let mut freq = vec![0u64; 32];
        for seed in 0..n {
            let mut sess = make_session(&world, policy, temp, seed as u64, 1);
            let res = sess.run(&[prev]).unwrap();
            let first = res.tokens[1];
            freq[first as usize] += 1;
        }
        let emp: Vec<f32> = freq.iter().map(|&c| c as f32 / n as f32).collect();
        let tv = tv_distance(&emp, &p_ref);
        // TV of an n-sample empirical distribution over 32 outcomes
        // concentrates near sqrt(V/(2*pi*n)) ~ 0.013; 0.03 is ~3 sigma.
        assert!(
            tv < 0.03,
            "{}: empirical TV {tv:.4} too far from target (SD guarantee broken?)",
            policy.name()
        );
    }
}

/// Acceptance must degrade as sparsification gets more aggressive (smaller
/// K drops more target mass), and dense QS must accept the most.
#[test]
fn acceptance_monotone_in_k() {
    let world = SyntheticWorld::new(64, 0.6, 3);
    let mut rates = Vec::new();
    for k in [1usize, 2, 8, 64] {
        let mut sess = make_session(&world, Policy::KSqs { k }, 1.0, 7, 400);
        let res = sess.run(&[9, 3, 1]).unwrap();
        rates.push(res.acceptance_rate());
    }
    for w in rates.windows(2) {
        assert!(
            w[1] >= w[0] - 0.03,
            "acceptance should not degrade with larger K: {rates:?}"
        );
    }
    assert!(rates[3] > rates[0] + 0.05, "K=64 must beat K=1 clearly: {rates:?}");
}

/// Per-batch distribution payload must respect the budget B (§4).
#[test]
fn budget_respected_for_all_policies() {
    let world = SyntheticWorld::new(64, 0.5, 1);
    for policy in [
        Policy::KSqs { k: 8 },
        Policy::CSqs { beta0: 0.01, alpha: 0.005, eta: 0.01 },
        Policy::DenseQs,
    ] {
        let mut sess = make_session(&world, policy, 0.9, 11, 200);
        let res = sess.run(&[1]).unwrap();
        for (i, b) in res.batches.iter().enumerate() {
            assert!(
                b.dist_bits <= 5000 || b.drafted == 1,
                "{} batch {i}: {} bits > B=5000 with {} drafts",
                policy.name(), b.dist_bits, b.drafted
            );
        }
    }
}

/// Theorem 2 certificate on the real protocol (not the synthetic-alpha
/// stream of the unit test): empirical mean dropped mass <= bound.
#[test]
fn theorem2_holds_in_protocol() {
    let world = SyntheticWorld::new(64, 0.7, 21);
    for (eta, alpha, beta0) in [
        (0.001f64, 0.0005f64, 0.01f64),   // the paper's operating point
        (0.01, 0.01, 0.05),
        (0.1, 0.05, 0.5),
    ] {
        let mut sess = make_session(
            &world,
            Policy::CSqs { beta0, alpha, eta },
            1.0,
            5,
            600,
        );
        let res = sess.run(&[2, 4]).unwrap();
        let emp = res.conformal_empirical_alpha.unwrap();
        let bound = res.conformal_bound.unwrap();
        assert!(
            emp <= bound + 1e-9,
            "eta={eta} alpha={alpha}: empirical {emp} > bound {bound}"
        );
        assert!(res.conformal_t.unwrap() > 0);
    }
}

/// eta = 0 disables adaptation: the threshold never moves, and the
/// Theorem 2 certificate degenerates (infinite bound).
#[test]
fn eta_zero_no_adaptation() {
    let world = SyntheticWorld::new(64, 0.7, 5);
    let mut sess = make_session(
        &world,
        Policy::CSqs { beta0: 0.02, alpha: 0.0005, eta: 0.0 },
        1.0,
        5,
        100,
    );
    let res = sess.run(&[2]).unwrap();
    assert!(res.conformal_bound.unwrap().is_infinite());
    let beta = sess.edge.conformal.as_ref().unwrap().beta();
    assert_eq!(beta, 0.02, "eta=0 must never move the threshold");
}

/// The latency ledger must be internally consistent and each component
/// must match its model.
#[test]
fn latency_ledger_consistent() {
    let world = SyntheticWorld::new(64, 0.5, 13);
    let mut sess = make_session(&world, Policy::KSqs { k: 8 }, 0.8, 3, 64);
    let res = sess.run(&[1, 2, 3]).unwrap();
    let sum = res.t_slm_s + res.t_uplink_s + res.t_llm_s + res.t_downlink_s;
    assert!((res.total_time_s - sum).abs() < 1e-12);
    // modeled compute: slm time = 1e-4 * total drafted
    let drafted: usize = res.batches.iter().map(|b| b.drafted).sum();
    assert!((res.t_slm_s - 1e-4 * drafted as f64).abs() < 1e-9);
    assert!((res.t_llm_s - 1e-3 * res.batches.len() as f64).abs() < 1e-9);
    // uplink time from the deterministic link formula
    let expect_up: f64 = res
        .batches
        .iter()
        .map(|b| b.frame_bits as f64 / 1e6 + 0.010)
        .sum();
    assert!((res.t_uplink_s - expect_up).abs() < 1e-9, "{} vs {expect_up}", res.t_uplink_s);
    let rr = res.resampling_rate();
    assert!((0.0..=1.0).contains(&rr));
    assert_eq!(res.n_rej, res.batches.iter().filter(|b| b.rejected).count());
}

/// Determinism: same seed, same trajectory; different seed diverges.
#[test]
fn deterministic_given_seed() {
    let world = SyntheticWorld::new(64, 0.5, 17);
    let run = |seed: u64| {
        let mut sess = make_session(
            &world,
            Policy::CSqs { beta0: 0.01, alpha: 0.001, eta: 0.01 },
            0.9,
            seed,
            50,
        );
        sess.run(&[4, 4]).unwrap().tokens
    };
    assert_eq!(run(123), run(123), "same seed, same trajectory");
    assert_ne!(run(123), run(124), "different seed should diverge");
}

/// With draft == target (mismatch 0) and a fine lattice, rejections are
/// bounded by the quantization distortion alone (Theorem 1 with zero
/// discrepancy term).
#[test]
fn identical_models_almost_never_reject() {
    let world = SyntheticWorld::new(32, 0.0, 9);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
    let link = SimulatedLink::new(LinkConfig::default(), 5);
    let cfg = SessionConfig {
        policy: Policy::DenseQs,
        temp: 1.0,
        ell: 4000, // fine lattice: V/(4*ell) = 0.002
        max_new_tokens: 300,
        seed: 5,
        timing: modeled(),
        ..Default::default()
    };
    let mut sess = SdSession::new(draft, target, link, cfg);
    let res = sess.run(&[8]).unwrap();
    assert!(
        res.resampling_rate() < 0.05,
        "identical models + fine lattice must almost never reject: rate={}",
        res.resampling_rate()
    );
}

/// Theorem 1 shape: the resampling rate should increase with draft–target
/// mismatch (the SLM–LLM discrepancy term).
#[test]
fn resampling_grows_with_mismatch() {
    let mut rates = Vec::new();
    for mismatch in [0.0, 0.5, 2.0] {
        let world = SyntheticWorld::new(64, mismatch, 31);
        let mut sess = make_session(&world, Policy::DenseQs, 1.0, 2, 400);
        let res = sess.run(&[3]).unwrap();
        rates.push(res.resampling_rate());
    }
    assert!(
        rates[2] > rates[0] + 0.1,
        "mismatch 2.0 must reject far more than 0.0: {rates:?}"
    );
}
