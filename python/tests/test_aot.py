"""AOT export integrity: manifests, weight blobs, HLO text round-trip.

Skipped (not failed) when artifacts have not been built yet — `make test`
always builds them first; bare `pytest` from a fresh checkout stays green
on the pure-python tests.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


EXPECTED_ARTIFACTS = [
    "slm_prefill", "slm_decode", "slm_decode_sqs",
    "llm_prefill", "llm_decode", "llm_verify", "sqs_kernel",
]


def test_all_artifacts_present(manifest):
    for name in EXPECTED_ARTIFACTS:
        assert name in manifest["artifacts"], name
        path = os.path.join(ART, manifest["artifacts"][name]["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 1000


def test_hlo_text_is_parseable_text(manifest):
    """HLO text (the 0.5.1-compatible interchange) — not a serialized proto."""
    for name in EXPECTED_ARTIFACTS:
        path = os.path.join(ART, manifest["artifacts"][name]["file"])
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, f"{name} missing HloModule header"
        assert "ENTRY" in open(path).read(), f"{name} missing ENTRY"


def test_weight_blobs_match_index(manifest):
    for m, info in manifest["models"].items():
        blob = os.path.join(ART, info["weights_bin"])
        size = os.path.getsize(blob)
        total = sum(e["numel"] * 4 for e in info["weights_index"])
        assert size == total, f"{m}: blob {size} != index {total}"
        assert info["params"] == sum(e["numel"] for e in info["weights_index"])
        # offsets are contiguous and ordered
        off = 0
        for e in info["weights_index"]:
            assert e["offset"] == off
            off += e["numel"] * 4


def test_weights_load_and_are_finite(manifest):
    for m, info in manifest["models"].items():
        blob = os.path.join(ART, info["weights_bin"])
        data = np.fromfile(blob, dtype="<f4")
        assert np.isfinite(data).all(), f"{m} has non-finite weights"
        assert np.abs(data).max() < 1e3


def test_models_actually_trained(manifest):
    """Final loss must beat the uniform-distribution baseline ln(256)=5.55
    by a wide margin; otherwise the SD acceptance dynamics are meaningless."""
    for m, info in manifest["models"].items():
        assert info["final_loss"] < 3.0, (m, info["final_loss"])


def test_decode_sqs_arg_spec(manifest):
    art = manifest["artifacts"]["slm_decode_sqs"]
    names = [a["name"] for a in art["args"]]
    assert names == ["token", "pos", "kv", "temp", "mode", "param", "ell"]
    assert art["outputs"] == ["counts", "alpha", "kept", "probs", "kv"]
    kv = art["args"][2]["shape"]
    slm = manifest["models"]["slm"]
    assert kv == [slm["n_layers"], 2, slm["s_max"], slm["d_model"]]
