"""L1 kernel correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes, seeds, and SQS parameters; these tests are the
normative correctness signal for everything the AOT artifacts contain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.sparse_quant import sparse_quantize, MODE_TOPK, MODE_THRESHOLD


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sq_blocks=st.integers(1, 4),
    block_q=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32, 40]),
    offset=st.integers(0, 128),
)
def test_attention_matches_ref(seed, sq_blocks, block_q, h, dh, offset):
    skv = 256
    sq = sq_blocks * block_q
    offset = min(offset, skv - sq)  # window must fit in the buffer
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((skv, h, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((skv, h, dh)), jnp.float32)
    got = attention(q, k, v, offset, block_q=block_q, block_k=64)
    want = ref.attention_ref(q, k, v, offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_causality():
    """Changing K/V strictly in the masked-out future must not change output."""
    rng = np.random.default_rng(7)
    sq, skv, h, dh = 16, 256, 2, 16
    offset = 40
    q = jnp.asarray(rng.standard_normal((sq, h, dh)), jnp.float32)
    k = rng.standard_normal((skv, h, dh)).astype(np.float32)
    v = rng.standard_normal((skv, h, dh)).astype(np.float32)
    out1 = attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), offset,
                     block_q=16)
    # poison everything beyond the last attendable column (offset+sq-1)
    k2, v2 = k.copy(), v.copy()
    k2[offset + sq:] = 1e3
    v2[offset + sq:] = -1e3
    out2 = attention(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), offset,
                     block_q=16)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_attention_rows_independent_of_padding_rows():
    """Row i only depends on columns <= offset+i (windowed causality)."""
    rng = np.random.default_rng(3)
    sq, skv, h, dh = 16, 128, 1, 8
    q = jnp.asarray(rng.standard_normal((sq, h, dh)), jnp.float32)
    k = rng.standard_normal((skv, h, dh)).astype(np.float32)
    v = rng.standard_normal((skv, h, dh)).astype(np.float32)
    base = np.asarray(attention(q, jnp.asarray(k), jnp.asarray(v), 0, block_q=16))
    # poison columns 8.. ; rows 0..7 must be unchanged
    k2, v2 = k.copy(), v.copy()
    k2[8:] = 50.0
    v2[8:] = -50.0
    out = np.asarray(attention(q, jnp.asarray(k2), jnp.asarray(v2), 0, block_q=16))
    np.testing.assert_allclose(out[:8], base[:8], atol=1e-6)


# ---------------------------------------------------------------------------
# sparse_quantize
# ---------------------------------------------------------------------------

def _rand_probs(rng, v, sharpness):
    logits = rng.standard_normal(v).astype(np.float32) * sharpness
    return np.asarray(jax.nn.softmax(jnp.asarray(logits)), np.float32)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    v=st.sampled_from([8, 32, 128, 256]),
    sharpness=st.floats(0.1, 8.0),
    mode=st.sampled_from([MODE_TOPK, MODE_THRESHOLD]),
    ell=st.sampled_from([10, 64, 100, 333, 1000]),
)
def test_sqs_kernel_matches_oracles(seed, v, sharpness, mode, ell):
    rng = np.random.default_rng(seed)
    q = _rand_probs(rng, v, sharpness)
    if mode == MODE_TOPK:
        param = float(rng.integers(1, v + 1))
    else:
        param = float(rng.uniform(0, 1.2 / np.sqrt(v)))
    counts, alpha, kept = sparse_quantize(jnp.asarray(q), mode, param, ell)
    cr, ar, kr = ref.sparse_quantize_ref(jnp.asarray(q), mode, param, ell)
    cn, an, kn = ref.sparse_quantize_np(q, mode, param, ell)
    counts = np.asarray(counts)
    assert (counts == np.asarray(cr)).all(), "pallas != jnp ref"
    assert (counts == cn).all(), "pallas != numpy ref"
    assert int(kept) == int(kr) == kn
    np.testing.assert_allclose(float(alpha), float(ar), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(float(alpha), float(an), rtol=1e-6, atol=1e-7)


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sharpness=st.floats(0.1, 8.0),
    k=st.integers(1, 256),
    ell=st.sampled_from([16, 100, 500]),
)
def test_sqs_topk_invariants(seed, sharpness, k, ell):
    rng = np.random.default_rng(seed)
    q = _rand_probs(rng, 256, sharpness)
    counts, alpha, kept = ref.sparse_quantize_np(q, MODE_TOPK, float(k), ell)
    assert counts.sum() == ell, "lattice counts must sum to ell"
    assert (counts >= 0).all()
    assert kept == k
    assert 0.0 <= alpha <= 1.0
    # support is exactly the top-k (counts nonzero only within it)
    order = np.argsort(-q.astype(np.float64), kind="stable")
    topk = set(order[:k].tolist())
    assert set(np.nonzero(counts)[0].tolist()) <= topk
    # TV(qbar, qhat) <= K/(4 ell)  — eq. (20) of the paper
    s = q[list(topk)].sum(dtype=np.float32)
    qbar = np.zeros_like(q)
    for i in topk:
        qbar[i] = q[i] / s
    tv = 0.5 * np.abs(qbar - counts / ell).sum()
    assert tv <= k / (4 * ell) + 1e-5


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sharpness=st.floats(0.1, 8.0),
    beta=st.floats(0.0, 1.5),
    ell=st.sampled_from([16, 100, 500]),
)
def test_sqs_threshold_invariants(seed, sharpness, beta, ell):
    rng = np.random.default_rng(seed)
    q = _rand_probs(rng, 256, sharpness)
    counts, alpha, kept = ref.sparse_quantize_np(q, MODE_THRESHOLD, beta, ell)
    assert counts.sum() == ell
    assert kept >= 1, "arg-max token always kept (Lemma 4 semantics)"
    # support = {q >= beta} U {argmax}
    expect = (q >= np.float32(beta))
    expect[np.argmax(q)] = True
    assert kept == expect.sum()
    # alpha equals the dropped mass by definition (Lemma 1)
    np.testing.assert_allclose(alpha, q[~expect].sum(dtype=np.float32),
                               rtol=1e-5, atol=1e-7)


def test_sqs_degenerate_top1():
    """beta > max(q): only the arg-max survives and gets all ell counts."""
    q = np.asarray(jax.nn.softmax(jnp.arange(16) * 0.1), np.float32)
    counts, alpha, kept = ref.sparse_quantize_np(q, MODE_THRESHOLD, 0.99, 100)
    assert kept == 1
    assert counts[15] == 100
    np.testing.assert_allclose(alpha, 1.0 - q[15], rtol=1e-6)


def test_softmax_t_sharpening():
    logits = jnp.asarray([1.0, 0.5, 0.0, -1.0])
    p_hi = np.asarray(ref.softmax_t(logits, 1.0))
    p_lo = np.asarray(ref.softmax_t(logits, 0.2))
    assert p_lo[0] > p_hi[0]          # lower temperature sharpens
    np.testing.assert_allclose(p_hi.sum(), 1.0, rtol=1e-6)
    np.testing.assert_allclose(p_lo.sum(), 1.0, rtol=1e-6)
