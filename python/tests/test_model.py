"""L2 model semantics: the serving phases must compose exactly.

prefill -> decode -> decode must equal a from-scratch full forward; the
verify window must reproduce the target model's per-position next-token
distributions.  These are the invariants the rust coordinator relies on
when it reuses KV caches across speculative batches and rolls back after
rejections.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus, model
from compile.kernels import ref as kref

CFG = model.Config(d_model=32, n_heads=2, n_layers=2, d_ff=64, s_max=64, ld1=8)


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def full_logits(params, toks):
    lg, _ = model.forward_window(CFG, params, jnp.asarray(toks, jnp.int32),
                                 jnp.asarray(0, jnp.int32), model.zero_kv(CFG),
                                 use_pallas=False)
    return np.asarray(lg)


def test_param_count_matches_config(params):
    n = sum(int(np.asarray(a).size) for a in model.params_flatten(CFG, params))
    assert n == CFG.param_count()


def test_flatten_roundtrip(params):
    flat = model.params_flatten(CFG, params)
    assert len(flat) == len(model.param_names(CFG))
    back = model.params_unflatten(CFG, flat)
    lg1 = full_logits(params, np.arange(10) % 256)
    lg2 = full_logits(back, np.arange(10) % 256)
    np.testing.assert_array_equal(lg1, lg2)


def test_prefill_matches_full_forward(params):
    toks = corpus.encode("The capital of France is")[: CFG.s_max].astype(np.int32)
    n = len(toks)
    buf = np.zeros(CFG.s_max, np.int32)
    buf[:n] = toks
    lg, _ = model.prefill(CFG, params, jnp.asarray(buf), jnp.asarray(n, jnp.int32),
                          use_pallas=False)
    np.testing.assert_allclose(np.asarray(lg), full_logits(params, toks)[n - 1],
                               rtol=1e-5, atol=1e-5)


def test_decode_chain_matches_full_forward(params):
    toks = corpus.encode("Once there was a fox")[: CFG.s_max].astype(np.int32)
    n = len(toks)
    buf = np.zeros(CFG.s_max, np.int32)
    buf[:n] = toks
    lg, kv = model.prefill(CFG, params, jnp.asarray(buf),
                           jnp.asarray(n, jnp.int32), use_pallas=False)
    seq = list(toks)
    pos = n
    for _ in range(5):
        nxt = int(jnp.argmax(lg))
        lg, kv = model.decode(CFG, params, jnp.asarray(nxt, jnp.int32),
                              jnp.asarray(pos, jnp.int32), kv)
        seq.append(nxt)
        pos += 1
        want = full_logits(params, np.asarray(seq, np.int32))[-1]
        np.testing.assert_allclose(np.asarray(lg), want, rtol=1e-4, atol=1e-4)


def test_decode_overwrite_position_is_rollback(params):
    """Re-decoding at the same position with a different token must equal a
    fresh context containing that token — the KV rollback contract."""
    toks = corpus.encode("The river ran")[: CFG.s_max].astype(np.int32)
    n = len(toks)
    buf = np.zeros(CFG.s_max, np.int32)
    buf[:n] = toks
    _, kv = model.prefill(CFG, params, jnp.asarray(buf),
                          jnp.asarray(n, jnp.int32), use_pallas=False)
    # decode token 'x' at position n, then pretend it was rejected and
    # decode token 'y' at the SAME position with the same cache object
    _, kv_after_x = model.decode(CFG, params, jnp.asarray(120, jnp.int32),
                                 jnp.asarray(n, jnp.int32), kv)
    lg_y, _ = model.decode(CFG, params, jnp.asarray(97, jnp.int32),
                           jnp.asarray(n, jnp.int32), kv_after_x)
    want = full_logits(params, np.concatenate([toks, [97]]))[-1]
    np.testing.assert_allclose(np.asarray(lg_y), want, rtol=1e-4, atol=1e-4)


def test_verify_window_matches_full_forward(params):
    ctx = corpus.encode("To make the bread, first")[: CFG.s_max - CFG.ld1]
    ctx = ctx.astype(np.int32)
    n = len(ctx)
    buf = np.zeros(CFG.s_max, np.int32)
    buf[:n] = ctx
    _, kv = model.prefill(CFG, params, jnp.asarray(buf),
                          jnp.asarray(n, jnp.int32), use_pallas=False)
    drafts = corpus.encode(" dissolv")[: CFG.ld1 - 1].astype(np.int32)
    window = np.zeros(CFG.ld1, np.int32)
    window[0] = ctx[-1]
    window[1: 1 + len(drafts)] = drafts
    temp = 0.8
    probs, _ = model.verify(CFG, params, jnp.asarray(window),
                            jnp.asarray(n - 1, jnp.int32), kv,
                            jnp.asarray(temp, jnp.float32), use_pallas=False)
    ext = np.concatenate([ctx, drafts])
    want = np.asarray(kref.softmax_t(
        jnp.asarray(full_logits(params, ext)[n - 1: n - 1 + len(drafts) + 1]),
        temp))
    np.testing.assert_allclose(np.asarray(probs)[: len(drafts) + 1], want,
                               rtol=1e-4, atol=1e-5)


def test_pallas_and_ref_paths_agree(params):
    """prefill with Pallas attention == prefill with jnp reference attention."""
    toks = corpus.encode("A distributed system is a collection")
    toks = toks[: CFG.s_max].astype(np.int32)
    n = len(toks)
    buf = np.zeros(CFG.s_max, np.int32)
    buf[:n] = toks
    lg_p, kv_p = model.prefill(CFG, params, jnp.asarray(buf),
                               jnp.asarray(n, jnp.int32), use_pallas=True)
    lg_r, kv_r = model.prefill(CFG, params, jnp.asarray(buf),
                               jnp.asarray(n, jnp.int32), use_pallas=False)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_r),
                               rtol=2e-4, atol=2e-4)


def test_corpus_roundtrip():
    s = "Hello, edge-cloud!"
    assert corpus.decode(corpus.encode(s)) == s
    assert corpus.corpus_bytes().max() < 256
    assert len(corpus.corpus_text()) > 3000
