"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a bit-for-bit-comparable reference
here; pytest + hypothesis sweep shapes and assert closeness.  The
`sparse_quantize` reference is *also* the normative specification of the
SQS wire semantics: the rust implementation (`rust/src/sqs/slq.rs`)
mirrors this function operation-for-operation (same tie-breaks, same f32
rounding), and an integration test cross-checks the two through the AOT
artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Attention reference
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, offset: int):
    """Windowed causal attention against a KV buffer.

    q: [Sq, H, Dh]  — query window, global positions offset..offset+Sq-1
    k, v: [Skv, H, Dh] — KV buffer (rows beyond offset+Sq-1 are ignored
        via the mask)
    Row i of the window may attend to buffer column j iff j <= offset + i.
    Returns [Sq, H, Dh].
    """
    sq, h, dh = q.shape
    skv = k.shape[0]
    scale = 1.0 / np.sqrt(dh)
    # [H, Sq, Skv]
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = cols <= (rows + offset)
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, v)


# ---------------------------------------------------------------------------
# Sparse-lattice quantization reference (Algorithm 2 + sparsification rules)
# ---------------------------------------------------------------------------

MODE_TOPK = 0
MODE_THRESHOLD = 1


def rank_desc(x, valid=None):
    """Rank of each element when sorting by (value desc, index asc).

    rank 0 = largest.  `valid` restricts the competition to a boolean mask
    (invalid entries get rank >= #valid and never win).
    Pure jnp, O(V^2) broadcast compares — the same trick the Pallas kernel
    uses to avoid data-dependent sorts on TPU.
    """
    n = x.shape[0]
    idx = jnp.arange(n)
    xi = x[None, :]  # j index
    xj = x[:, None]  # i index
    beats = (xi > xj) | ((xi == xj) & (idx[None, :] < idx[:, None]))
    if valid is not None:
        beats = beats & valid[None, :]
        # invalid entries lose to everything valid
        rank = jnp.sum(beats, axis=1)
        rank = jnp.where(valid, rank, n)
    else:
        rank = jnp.sum(beats, axis=1)
    return rank


def sparse_quantize_ref(q, mode, param, ell):
    """Fused sparsify + sparse-lattice-quantize (SLQ), jnp reference.

    q:     [V] f32 probability vector (sums to 1)
    mode:  MODE_TOPK (param = K) or MODE_THRESHOLD (param = beta)
    ell:   lattice resolution (positive int)

    Returns (counts i32[V], alpha f32, kept i32) where
      counts/ell is the quantized distribution q_hat (sums to exactly ell),
      alpha is the probability mass dropped by sparsification, and
      kept = |support|.

    Follows Algorithm 2 of the paper with deterministic index tie-breaks;
    when thresholding would empty the support, the arg-max token is kept
    (the paper's Lemma 4 semantics for beta > max q).
    """
    v = q.shape[0]
    r = rank_desc(q)
    mode = jnp.asarray(mode, jnp.int32)
    param = jnp.asarray(param, jnp.float32)
    ell_f = jnp.asarray(ell, jnp.float32)

    keep_topk = r < param.astype(jnp.int32)
    keep_thr = (q >= param) | (r == 0)
    keep = jnp.where(mode == MODE_TOPK, keep_topk, keep_thr)

    alpha = jnp.sum(jnp.where(keep, 0.0, q))
    s = jnp.sum(jnp.where(keep, q, 0.0))
    qbar = jnp.where(keep, q / s, 0.0)

    b = jnp.floor(ell_f * qbar + 0.5)
    d = (jnp.sum(b) - ell_f).astype(jnp.int32)  # surplus (can be +/-)
    zeta = b - ell_f * qbar  # rounding residual in [-0.5, 0.5]

    # d > 0: decrement the d kept entries with the largest zeta
    rz_hi = rank_desc(zeta, valid=keep)
    dec = keep & (rz_hi < d)
    # d < 0: increment the |d| kept entries with the smallest zeta
    rz_lo = rank_desc(-zeta, valid=keep)
    inc = keep & (rz_lo < (-d))
    b = b - jnp.where(dec, 1.0, 0.0) + jnp.where(inc, 1.0, 0.0)

    counts = b.astype(jnp.int32)
    return counts, alpha.astype(jnp.float32), jnp.sum(keep).astype(jnp.int32)


def sparse_quantize_np(q: np.ndarray, mode: int, param: float, ell: int):
    """Plain-numpy restatement (used by python tests as a second oracle)."""
    v = q.shape[0]
    order = np.lexsort((np.arange(v), -q.astype(np.float64)))
    rank = np.empty(v, dtype=np.int64)
    rank[order] = np.arange(v)
    if mode == MODE_TOPK:
        keep = rank < int(param)
    else:
        keep = (q >= np.float32(param)) | (rank == 0)
    alpha = np.float32(q[~keep].sum(dtype=np.float32))
    s = np.float32(q[keep].sum(dtype=np.float32))
    qbar = np.where(keep, (q / s).astype(np.float32), np.float32(0.0))
    b = np.floor(np.float32(ell) * qbar + np.float32(0.5)).astype(np.int64)
    d = int(b.sum()) - int(ell)
    zeta = b.astype(np.float32) - np.float32(ell) * qbar
    if d > 0:
        cand = np.lexsort((np.arange(v), -zeta.astype(np.float64)))
        cand = [i for i in cand if keep[i]][:d]
        b[cand] -= 1
    elif d < 0:
        cand = np.lexsort((np.arange(v), zeta.astype(np.float64)))
        cand = [i for i in cand if keep[i]][: -d]
        b[np.asarray(cand, dtype=np.int64)] += 1
    return b.astype(np.int32), alpha, int(keep.sum())


def softmax_t(logits, temp):
    """Temperature softmax; temp -> 0 approaches argmax (clamped for safety)."""
    t = jnp.maximum(jnp.asarray(temp, jnp.float32), 1e-4)
    z = logits / t
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)
