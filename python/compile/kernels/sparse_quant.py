"""Fused sparsify + sparse-lattice-quantize (SQS) as a Pallas kernel (L1).

This is the paper's per-token compute hot-spot on the edge device: given
the SLM's next-token distribution q, (i) select the support — top-K (K-SQS)
or threshold beta (C-SQS, eq. (6)) — (ii) renormalize, (iii) project onto
the lattice {b/ell : sum b = ell} with the largest-remainder correction of
Algorithm 2, and (iv) report the dropped mass alpha_n used by the online
conformal update (eq. (8)).

TPU adaptation (DESIGN.md §3): data-dependent sorts are hostile to the
TPU's vector unit, so both the top-K selection and the largest-remainder
correction are done by *rank computation* — O(V^2) broadcast comparisons
that lower to dense VPU ops.  At V=256 the V x V compare tile is 256 KiB
in VMEM, far below budget; FLOPs are traded for the absence of control
flow, the classic TPU move.

Lowered with `interpret=True` (see attention.py for why) and AOT-exported
both standalone (`sqs_kernel.hlo.txt`, for rust<->python cross-checks) and
fused after the SLM decode step (`slm_decode_sqs.hlo.txt`).

Semantics are defined by `ref.sparse_quantize_ref`; tie-breaks are by
ascending index everywhere, so the rust mirror can reproduce them exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MODE_TOPK = 0
MODE_THRESHOLD = 1


def _rank_desc_block(x, valid, n):
    """rank[i] = #{j : valid_j and (x_j > x_i or (x_j == x_i and j < i))}.

    Invalid entries receive rank n so they never win a `rank < d` contest.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    idx_t = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    xi = x[None, :]
    xj = x[:, None]
    beats = (xi > xj) | ((xi == xj) & (idx < idx_t))
    beats = beats & valid[None, :]
    rank = jnp.sum(beats.astype(jnp.int32), axis=1)
    return jnp.where(valid, rank, n)


def _sqs_kernel(q_ref, mode_ref, param_ref, ell_ref,
                counts_ref, alpha_ref, kept_ref):
    q = q_ref[...].astype(jnp.float32)  # [V]
    v = q.shape[0]
    mode = mode_ref[0]
    param = param_ref[0]
    ell_i = ell_ref[0]
    ell_f = ell_i.astype(jnp.float32)

    all_valid = jnp.ones((v,), jnp.bool_)
    r = _rank_desc_block(q, all_valid, v)

    keep_topk = r < param.astype(jnp.int32)
    keep_thr = (q >= param) | (r == 0)
    keep = jnp.where(mode == MODE_TOPK, keep_topk, keep_thr)

    alpha = jnp.sum(jnp.where(keep, 0.0, q))
    s = jnp.sum(jnp.where(keep, q, 0.0))
    qbar = jnp.where(keep, q / s, 0.0)

    b = jnp.floor(ell_f * qbar + 0.5)
    d = (jnp.sum(b) - ell_f).astype(jnp.int32)
    zeta = b - ell_f * qbar

    rz_hi = _rank_desc_block(zeta, keep, v)
    rz_lo = _rank_desc_block(-zeta, keep, v)
    dec = keep & (rz_hi < d)
    inc = keep & (rz_lo < (-d))
    b = b - jnp.where(dec, 1.0, 0.0) + jnp.where(inc, 1.0, 0.0)

    counts_ref[...] = b.astype(jnp.int32)
    alpha_ref[0] = alpha
    kept_ref[0] = jnp.sum(keep.astype(jnp.int32))


def sparse_quantize(q, mode, param, ell, *, interpret: bool = True):
    """Pallas-fused SQS quantizer.

    q: [V] f32 probabilities; mode: scalar i32; param: scalar f32
    (K for top-K mode, beta for threshold mode); ell: scalar i32.

    Returns (counts i32[V], alpha f32, kept i32).
    """
    v = q.shape[0]
    mode_a = jnp.reshape(jnp.asarray(mode, jnp.int32), (1,))
    param_a = jnp.reshape(jnp.asarray(param, jnp.float32), (1,))
    ell_a = jnp.reshape(jnp.asarray(ell, jnp.int32), (1,))

    counts, alpha, kept = pl.pallas_call(
        _sqs_kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((v,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((v,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(q, mode_a, param_a, ell_a)
    return counts, alpha[0], kept[0]
