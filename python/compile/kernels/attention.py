"""Tiled causal/windowed attention as a Pallas kernel (L1).

TPU-idiomatic flash attention: the query window is tiled into VMEM-resident
blocks via `BlockSpec` (the role threadblock shared-memory staging plays on
GPU), K/V are streamed block-by-block with an online-softmax accumulator,
so the Sq x Skv score matrix is never materialized.  Matmul shapes are MXU
friendly (block sizes multiples of 8); accumulation is f32.

Lowered with `interpret=True`: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path and the form
that is AOT-exported into the HLO artifacts.  Real-TPU perf is *estimated*
(VMEM footprint / MXU utilization) in DESIGN.md §7 — interpret wallclock is
not a perf proxy.

Semantics match `ref.attention_ref`: query row i (global position
offset + i) attends to KV buffer columns j <= offset + i.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int, skv: int, scale: float):
    """One program = one (head, q-block). K/V streamed in block_k chunks."""
    q = q_ref[0].astype(jnp.float32)  # block shape (1, Bq, Dh) -> [Bq, Dh]
    bq, dh = q.shape
    offset = off_ref[0]
    qi = pl.program_id(1)  # q-block index
    row0 = qi * bq  # first window-row of this block

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)  # running max
    l0 = jnp.zeros((bq,), jnp.float32)  # running denom
    acc0 = jnp.zeros((bq, dh), jnp.float32)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(kb * block_k, block_k), slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(kb * block_k, block_k), slice(None)))[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [Bq, Bk]
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
        cols = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        s = jnp.where(cols <= rows + offset, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    nkb = skv // block_k
    m, l, acc = jax.lax.fori_loop(0, nkb, body, (m0, l0, acc0))
    # Fully masked rows (can't happen for valid windows, but keep safe):
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, ...] = (acc / l[:, None]).astype(o_ref.dtype)


def attention(q, k, v, offset, *, block_q: int | None = None, block_k: int = 64,
              interpret: bool = True):
    """Flash attention over a query window against a KV buffer.

    q: [Sq, H, Dh]; k, v: [Skv, H, Dh]; offset: scalar i32 (global position
    of window row 0).  Returns [Sq, H, Dh] in q.dtype.
    """
    sq, h, dh = q.shape
    skv = k.shape[0]
    if block_q is None:
        block_q = min(64, sq)
    assert sq % block_q == 0, f"Sq={sq} not divisible by block_q={block_q}"
    assert skv % block_k == 0, f"Skv={skv} not divisible by block_k={block_k}"
    scale = 1.0 / np.sqrt(dh)
    off = jnp.reshape(jnp.asarray(offset, jnp.int32), (1,))

    # [H, S, Dh] layout so the grid can tile (head, q-block).
    qh = jnp.transpose(q, (1, 0, 2))
    kh = jnp.transpose(k, (1, 0, 2))
    vh = jnp.transpose(v, (1, 0, 2))

    kern = functools.partial(_attn_kernel, block_k=block_k, skv=skv, scale=scale)
    out = pl.pallas_call(
        kern,
        grid=(h, sq // block_q),
        in_specs=[
            pl.BlockSpec((1,), lambda hi, qi: (0,)),              # offset (replicated)
            pl.BlockSpec((1, block_q, dh), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, skv, dh), lambda hi, qi: (hi, 0, 0)),  # stream inside
            pl.BlockSpec((1, skv, dh), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, dh), q.dtype),
        interpret=interpret,
    )(off, qh, kh, vh)
    return jnp.transpose(out, (1, 0, 2))
