"""AOT export: lower the L2 model (+ fused L1 kernels) to HLO text artifacts.

`python -m compile.aot --out-dir ../artifacts` produces:

  slm_prefill.hlo.txt   llm_prefill.hlo.txt
  slm_decode.hlo.txt    llm_decode.hlo.txt
  slm_decode_sqs.hlo.txt            (decode fused with the SQS Pallas kernel)
  llm_verify.hlo.txt                (parallel verification window)
  sqs_kernel.hlo.txt                (standalone kernel, rust cross-check)
  weights_slm.bin / weights_llm.bin (flat f32 tensors, manifest-indexed)
  manifest.json                     (shapes, arg order, configs, corpus)

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Model weights are runtime *inputs* (flat, ordered per `model.param_names`),
not baked constants: HLO stays small, and the rust runtime uploads weights
once as device-resident PJRT buffers — the same shape real serving takes.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model, train
from .kernels.sparse_quant import sparse_quantize


def to_hlo_text(lowered) -> str:
    # return_tuple=False: multi-output executables return one PJRT buffer
    # per output, so the rust runtime can keep the KV cache device-resident
    # across calls (execute_b) without host round-trips.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _scalar_i32():
    return _spec((), jnp.int32)


def _scalar_f32():
    return _spec((), jnp.float32)


def kv_spec(cfg: model.Config):
    return _spec((cfg.n_layers, 2, cfg.s_max, cfg.d_model), jnp.float32)


def param_specs(cfg: model.Config, params):
    return [_spec(p.shape, p.dtype) for p in model.params_flatten(cfg, params)]


def build_exports(cfg: model.Config, params, name: str, use_pallas: bool):
    """Return {artifact_name: (fn taking (*flat_params, *args), arg_specs, arg_names, out_names)}."""
    n_flat = len(model.param_names(cfg))

    def with_params(f):
        def g(*all_args):
            flat, rest = all_args[:n_flat], all_args[n_flat:]
            p = model.params_unflatten(cfg, flat)
            return f(p, *rest)
        return g

    exports = {}

    exports[f"{name}_prefill"] = (
        with_params(lambda p, tokens, n:
                    model.prefill(cfg, p, tokens, n, use_pallas=use_pallas)),
        [_spec((cfg.s_max,), jnp.int32), _scalar_i32()],
        ["tokens", "n"],
        ["logits", "kv"],
    )
    exports[f"{name}_decode"] = (
        with_params(lambda p, token, pos, kv: model.decode(cfg, p, token, pos, kv)),
        [_scalar_i32(), _scalar_i32(), kv_spec(cfg)],
        ["token", "pos", "kv"],
        ["logits", "kv"],
    )
    if name == "slm":
        def decode_sqs(p, token, pos, kv, temp, mode, param, ell):
            logits, kv2 = model.decode(cfg, p, token, pos, kv)
            from .kernels import ref as kref
            q = kref.softmax_t(logits, temp)
            counts, alpha, kept = sparse_quantize(q, mode, param, ell)
            return counts, alpha, kept, q, kv2

        exports["slm_decode_sqs"] = (
            with_params(decode_sqs),
            [_scalar_i32(), _scalar_i32(), kv_spec(cfg), _scalar_f32(),
             _scalar_i32(), _scalar_f32(), _scalar_i32()],
            ["token", "pos", "kv", "temp", "mode", "param", "ell"],
            ["counts", "alpha", "kept", "probs", "kv"],
        )
    if name == "llm":
        exports["llm_verify"] = (
            with_params(lambda p, tokens, start, kv, temp:
                        model.verify(cfg, p, tokens, start, kv, temp,
                                     use_pallas=use_pallas)),
            [_spec((cfg.ld1,), jnp.int32), _scalar_i32(), kv_spec(cfg),
             _scalar_f32()],
            ["tokens", "start", "kv", "temp"],
            ["probs", "kv"],
        )
    return exports


def write_weights_bin(path: str, cfg: model.Config, params):
    """Flat little-endian f32 tensors, concatenated in manifest order."""
    index = []
    offset = 0
    with open(path, "wb") as f:
        for nm, arr in zip(model.param_names(cfg),
                           model.params_flatten(cfg, params)):
            a = np.asarray(arr, dtype="<f4")
            f.write(a.tobytes())
            index.append(dict(name=nm, shape=list(a.shape),
                              dtype="f32", offset=offset, numel=int(a.size)))
            offset += a.size * 4
    return index


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--no-pallas", action="store_true",
                    help="export with jnp reference attention (debug)")
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    use_pallas = not args.no_pallas

    models = {}
    # SLM trains longer than its size suggests: the draft must be a decent
    # approximation of the target for speculative acceptance rates to land
    # in the paper's regime (GPT-Neo-125M is a *good* model; an
    # undertrained draft makes every experiment rejection-dominated).
    slm_params, slm_loss = train.load_or_train(
        model.SLM_CONFIG, os.path.join(out, "weights_slm.npz"),
        steps=2500, batch=16, seq_len=96, lr=3e-3, seed=1, name="slm",
        retrain=args.retrain)
    llm_params, llm_loss = train.load_or_train(
        model.LLM_CONFIG, os.path.join(out, "weights_llm.npz"),
        steps=1100, batch=16, seq_len=96, lr=1e-3, seed=2, name="llm",
        retrain=args.retrain)
    models["slm"] = (model.SLM_CONFIG, slm_params, slm_loss)
    models["llm"] = (model.LLM_CONFIG, llm_params, llm_loss)

    manifest = {
        "vocab": model.SLM_CONFIG.vocab,
        "corpus_sha": corpus.corpus_sha(),
        "prompts": corpus.PROMPTS,
        "models": {},
        "artifacts": {},
    }

    for name, (cfg, params, loss) in models.items():
        manifest["models"][name] = {
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "d_ff": cfg.d_ff,
            "s_max": cfg.s_max, "ld1": cfg.ld1, "vocab": cfg.vocab,
            "params": cfg.param_count(), "final_loss": loss,
            "weights_bin": f"weights_{name}.bin",
            "weights_index": write_weights_bin(
                os.path.join(out, f"weights_{name}.bin"), cfg, params),
        }
        flat_specs = param_specs(cfg, params)
        for art, (fn, arg_specs, arg_names, out_names) in build_exports(
                cfg, params, name, use_pallas).items():
            print(f"[aot] lowering {art} ...", flush=True)
            lowered = jax.jit(fn).lower(*(flat_specs + arg_specs))
            text = to_hlo_text(lowered)
            fname = f"{art}.hlo.txt"
            with open(os.path.join(out, fname), "w") as f:
                f.write(text)
            manifest["artifacts"][art] = {
                "file": fname, "model": name,
                "args": [
                    {"name": nm, "shape": list(sp.shape),
                     "dtype": str(np.dtype(sp.dtype))}
                    for nm, sp in zip(arg_names, arg_specs)],
                "outputs": out_names,
                "n_weight_args": len(flat_specs),
                "hlo_bytes": len(text),
            }
            print(f"[aot]   wrote {fname} ({len(text)} bytes)", flush=True)

    # Standalone SQS kernel (no model), for rust<->python cross-checks.
    v = model.SLM_CONFIG.vocab
    print("[aot] lowering sqs_kernel ...", flush=True)
    lowered = jax.jit(lambda q, mode, param, ell:
                      sparse_quantize(q, mode, param, ell)).lower(
        _spec((v,), jnp.float32), _scalar_i32(), _scalar_f32(), _scalar_i32())
    text = to_hlo_text(lowered)
    with open(os.path.join(out, "sqs_kernel.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"]["sqs_kernel"] = {
        "file": "sqs_kernel.hlo.txt", "model": None,
        "args": [{"name": "q", "shape": [v], "dtype": "float32"},
                 {"name": "mode", "shape": [], "dtype": "int32"},
                 {"name": "param", "shape": [], "dtype": "float32"},
                 {"name": "ell", "shape": [], "dtype": "int32"}],
        "outputs": ["counts", "alpha", "kept"],
        "n_weight_args": 0,
        "hlo_bytes": len(text),
    }

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; {len(manifest['artifacts'])} artifacts",
          flush=True)


if __name__ == "__main__":
    main()
