"""Build-time training of the SLM (draft) and LLM (target) models.

Runs once under `make artifacts`; weights are cached in
`artifacts/weights_{slm,llm}.npz` so subsequent artifact builds skip
training.  Adam is hand-rolled (optax is not a guaranteed dependency of
this image).  Training uses the jnp reference attention — interpret-mode
Pallas in the step function would dominate wallclock; kernel parity is
guaranteed separately by the kernel test suite.
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model


def tree_zeros_like(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** step), m)
    vhat = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** step), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat)
    return params, m, v


def train_model(cfg: model.Config, *, steps: int, batch: int, seq_len: int,
                lr: float, seed: int, log_every: int = 50,
                name: str = "model") -> Tuple[Dict[str, Any], float]:
    rng = np.random.default_rng(seed)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))

    @jax.jit
    def step_fn(params, m, v, step, batch_tokens):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch_tokens))(params)
        params, m, v = adam_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    m = tree_zeros_like(params)
    v = tree_zeros_like(params)
    t0 = time.time()
    loss = float("nan")
    for i in range(1, steps + 1):
        bt = jnp.asarray(corpus.sample_batch(rng, batch, seq_len))
        params, m, v, loss = step_fn(params, m, v, jnp.asarray(i, jnp.float32), bt)
        if i % log_every == 0 or i == 1:
            print(f"[train:{name}] step {i}/{steps} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params, float(loss)


def params_to_npz(params) -> Dict[str, np.ndarray]:
    flat = {}
    flat["tok_emb"] = np.asarray(params["tok_emb"])
    flat["pos_emb"] = np.asarray(params["pos_emb"])
    flat["lnf_g"] = np.asarray(params["lnf_g"])
    flat["lnf_b"] = np.asarray(params["lnf_b"])
    for i, blk in enumerate(params["blocks"]):
        for k, a in blk.items():
            flat[f"b{i}_{k}"] = np.asarray(a)
    return flat


def params_from_npz(cfg: model.Config, data) -> Dict[str, Any]:
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append({k: jnp.asarray(data[f"b{i}_{k}"])
                       for k in ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                                  "ln2_g", "ln2_b", "w1", "b1", "w2", "b2")})
    return dict(tok_emb=jnp.asarray(data["tok_emb"]),
                pos_emb=jnp.asarray(data["pos_emb"]),
                blocks=blocks,
                lnf_g=jnp.asarray(data["lnf_g"]),
                lnf_b=jnp.asarray(data["lnf_b"]))


def load_or_train(cfg: model.Config, path: str, *, steps: int, batch: int,
                  seq_len: int, lr: float, seed: int, name: str,
                  retrain: bool = False):
    if os.path.exists(path) and not retrain:
        data = np.load(path)
        loss = float(data["final_loss"]) if "final_loss" in data else float("nan")
        print(f"[train:{name}] loaded cached weights from {path} "
              f"(loss {loss:.4f})", flush=True)
        return params_from_npz(cfg, data), loss
    fast = os.environ.get("SQS_FAST", "") not in ("", "0")
    if fast:
        steps = max(20, steps // 10)
        print(f"[train:{name}] SQS_FAST set -> {steps} steps", flush=True)
    params, loss = train_model(cfg, steps=steps, batch=batch, seq_len=seq_len,
                               lr=lr, seed=seed, name=name)
    flat = params_to_npz(params)
    flat["final_loss"] = np.asarray(loss)
    np.savez(path, **flat)
    print(f"[train:{name}] saved weights to {path}", flush=True)
    return params, loss
