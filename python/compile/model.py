"""L2: byte-level GPT-style transformer in JAX, calling the L1 kernels.

One forward primitive — `forward_window` — serves every serving phase:

  * prefill : window = the whole token buffer, start = 0
  * decode  : window = 1 token at position `pos` (draft loop / AR baseline)
  * verify  : window = LD1 consecutive tokens starting at `start`
              (the last accepted token + up to LD1-1 draft tokens)

The KV cache is an explicit functional value `[n_layers, 2, s_max, d]`
(rust owns the buffers; see rust/src/model/kv.rs).  `forward_window`
writes the window's K/V rows into the cache *before* attending, so
re-decoding a position after a speculative rejection simply overwrites the
stale rows — KV rollback is a position-counter reset, never a copy.

Architecture: pre-LN, learned positional embeddings, GELU MLP, weight-tied
LM head.  Attention goes through the Pallas flash kernel when the window
is block-aligned (prefill/verify), through the jnp reference otherwise
(decode's single-row query; also training, where interpret-mode Pallas
would dominate step time).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels.attention import attention as pallas_attention

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Config:
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 256
    s_max: int = 256      # KV buffer length == max sequence length
    ld1: int = 16         # verify window: 1 context token + up to 15 drafts

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, l, v, s = self.d_model, self.d_ff, self.n_layers, self.vocab, self.s_max
        per_block = 4 * d * d + 2 * d * f + f + d + 4 * d
        return v * d + s * d + l * per_block + 2 * d


# SLM (edge draft) and LLM (cloud target) configurations.  The paper uses
# GPT-Neo-125M / 1.3B; these are laptop-scale substitutes with the same
# ~6x parameter ratio trained on the same corpus (DESIGN.md §2).
SLM_CONFIG = Config(d_model=64, n_heads=2, n_layers=2, d_ff=256)
LLM_CONFIG = Config(d_model=160, n_heads=4, n_layers=4, d_ff=640)


def init_params(cfg: Config, key: jax.Array) -> Params:
    """Scaled-normal init (GPT-2 style: residual projections down-scaled)."""
    keys = jax.random.split(key, 2 + cfg.n_layers)
    std = 0.02
    resid_std = std / np.sqrt(2 * cfg.n_layers)
    d, f = cfg.d_model, cfg.d_ff

    def nrm(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s)

    blocks: List[Params] = []
    for i in range(cfg.n_layers):
        bk = jax.random.split(keys[2 + i], 6)
        blocks.append(dict(
            ln1_g=jnp.ones((d,)), ln1_b=jnp.zeros((d,)),
            wq=nrm(bk[0], (d, d), std), wk=nrm(bk[1], (d, d), std),
            wv=nrm(bk[2], (d, d), std), wo=nrm(bk[3], (d, d), resid_std),
            ln2_g=jnp.ones((d,)), ln2_b=jnp.zeros((d,)),
            w1=nrm(bk[4], (d, f), std), b1=jnp.zeros((f,)),
            w2=nrm(bk[5], (f, d), resid_std), b2=jnp.zeros((d,)),
        ))
    return dict(
        tok_emb=nrm(keys[0], (cfg.vocab, d), std),
        pos_emb=nrm(keys[1], (cfg.s_max, d), std),
        blocks=blocks,
        lnf_g=jnp.ones((d,)), lnf_b=jnp.zeros((d,)),
    )


# Flat parameter ordering shared with the rust runtime (manifest.json lists
# the same names/shapes; rust uploads the tensors once as device buffers and
# passes them positionally before the per-call inputs).
_BLOCK_KEYS = ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
               "ln2_g", "ln2_b", "w1", "b1", "w2", "b2")


def param_names(cfg: Config) -> List[str]:
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [f"b{i}_{k}" for k in _BLOCK_KEYS]
    names += ["lnf_g", "lnf_b"]
    return names


def params_flatten(cfg: Config, params: Params) -> List[jnp.ndarray]:
    flat = [params["tok_emb"], params["pos_emb"]]
    for blk in params["blocks"]:
        flat += [blk[k] for k in _BLOCK_KEYS]
    flat += [params["lnf_g"], params["lnf_b"]]
    return flat


def params_unflatten(cfg: Config, flat) -> Params:
    flat = list(flat)
    tok_emb, pos_emb = flat[0], flat[1]
    blocks = []
    off = 2
    for _ in range(cfg.n_layers):
        blocks.append(dict(zip(_BLOCK_KEYS, flat[off:off + len(_BLOCK_KEYS)])))
        off += len(_BLOCK_KEYS)
    return dict(tok_emb=tok_emb, pos_emb=pos_emb, blocks=blocks,
                lnf_g=flat[off], lnf_b=flat[off + 1])


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def zero_kv(cfg: Config) -> jnp.ndarray:
    return jnp.zeros((cfg.n_layers, 2, cfg.s_max, cfg.d_model), jnp.float32)


def forward_window(cfg: Config, params: Params, tokens: jnp.ndarray,
                   start: jnp.ndarray, kv: jnp.ndarray,
                   use_pallas: bool = True):
    """Run `W = tokens.shape[0]` positions starting at `start` through the model.

    tokens: [W] i32; start: scalar i32; kv: [L, 2, S, d] f32.
    Returns (logits [W, V] f32, kv' [L, 2, S, d]).

    Window row i is global position start+i and attends to cache columns
    j <= start+i (its own K/V row included — written before attending).
    """
    w = tokens.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    pos = start + jnp.arange(w)
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]

    new_kv = []
    for li, blk in enumerate(params["blocks"]):
        xn = _ln(x, blk["ln1_g"], blk["ln1_b"])
        q = xn @ blk["wq"]
        k_new = xn @ blk["wk"]
        v_new = xn @ blk["wv"]
        k_buf = jax.lax.dynamic_update_slice(kv[li, 0], k_new, (start, 0))
        v_buf = jax.lax.dynamic_update_slice(kv[li, 1], v_new, (start, 0))
        qh = q.reshape(w, h, dh)
        kh = k_buf.reshape(cfg.s_max, h, dh)
        vh = v_buf.reshape(cfg.s_max, h, dh)
        if use_pallas and w % 8 == 0 and w >= 8:
            att = pallas_attention(qh, kh, vh, start,
                                   block_q=min(64, w), block_k=64)
        else:
            att = kref.attention_ref(qh, kh, vh, start)
        x = x + att.reshape(w, cfg.d_model) @ blk["wo"]
        xn2 = _ln(x, blk["ln2_g"], blk["ln2_b"])
        hdn = jax.nn.gelu(xn2 @ blk["w1"] + blk["b1"])
        x = x + hdn @ blk["w2"] + blk["b2"]
        new_kv.append(jnp.stack([k_buf, v_buf]))

    x = _ln(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T
    return logits, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# Serving-phase wrappers (these are what aot.py lowers to HLO)
# ---------------------------------------------------------------------------

def prefill(cfg: Config, params: Params, tokens: jnp.ndarray, n: jnp.ndarray,
            use_pallas: bool = True):
    """Process the whole padded buffer; return logits at position n-1 + cache.

    tokens: [s_max] i32 (positions >= n are padding; their K/V rows are
    garbage but are overwritten by decode/verify before ever being
    attended to — see forward_window's write-before-attend contract).
    """
    logits, kv = forward_window(cfg, params, tokens, jnp.asarray(0, jnp.int32),
                                zero_kv(cfg), use_pallas=use_pallas)
    last = jnp.take(logits, n - 1, axis=0)
    return last, kv


def decode(cfg: Config, params: Params, token: jnp.ndarray, pos: jnp.ndarray,
           kv: jnp.ndarray):
    """Single-token decode step: logits for position pos+1's prediction."""
    logits, kv = forward_window(cfg, params, jnp.reshape(token, (1,)), pos, kv,
                                use_pallas=False)
    return logits[0], kv


def verify(cfg: Config, params: Params, tokens: jnp.ndarray, start: jnp.ndarray,
           kv: jnp.ndarray, temp: jnp.ndarray, use_pallas: bool = True):
    """Verify window: probs (temperature softmax) for ld1 positions.

    tokens: [ld1] = [last accepted token, draft_1 .. draft_{ld1-1}] (padded).
    probs[i] is the target model's next-token distribution *after* seeing
    tokens[:i+1] — i.e. the distribution draft_{i+1} is verified against.
    """
    logits, kv = forward_window(cfg, params, tokens, start, kv,
                                use_pallas=use_pallas)
    return kref.softmax_t(logits, temp), kv


def loss_fn(cfg: Config, params: Params, batch: jnp.ndarray):
    """Mean next-token cross-entropy; batch [B, T+1] i32."""
    inp, tgt = batch[:, :-1], batch[:, 1:]
    b, t = inp.shape

    def single(tok):
        logits, _ = forward_window(
            cfg, params, tok, jnp.asarray(0, jnp.int32),
            jnp.zeros((cfg.n_layers, 2, cfg.s_max, cfg.d_model), jnp.float32),
            use_pallas=False)
        return logits[:t]

    logits = jax.vmap(single)(inp)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
