"""Embedded build-time corpus for training the edge SLM and cloud LLM.

The paper trains nothing (it uses pretrained GPT-Neo-125M / 1.3B on LM1B);
this repo cannot download either, so we substitute: a small public-domain
style text corpus embedded in the source tree, on which *both* models are
trained at `make artifacts` time.  What matters for reproducing the paper's
dynamics is that (a) the draft and target models are statistically
correlated (so speculative acceptance rates are realistic) and (b) the
per-token uncertainty varies with context and with sampling temperature.
A byte-level vocabulary (V=256) keeps the tokenizer trivially mirrored in
rust while preserving the sparse "most mass in a few tokens" structure the
paper exploits.
"""

from __future__ import annotations

import hashlib

import numpy as np

VOCAB_SIZE = 256

# ~8 KB of varied English prose, tiled with shuffling at sampling time.
# Mixed registers (narrative, technical, dialogue, lists) give the draft
# model contexts of very different predictability — the property C-SQS's
# adaptive threshold is designed to exploit (paper §3, "The capital of
# France is" vs "She opened the box and found").
_PARAGRAPHS = [
    "The river ran slow and brown past the old mill, and the miller's "
    "daughter counted barges from the window. One, two, three, she said, "
    "and the fourth barge carried salt, and the fifth carried nothing at "
    "all. In the evening the water turned the color of tea and the lamps "
    "came on one by one along the towpath.",
    "A distributed system is a collection of independent computers that "
    "appears to its users as a single coherent system. The first goal is "
    "to hide the fact that processes and resources are physically "
    "distributed across multiple machines. Communication latency, partial "
    "failure, and concurrency are the three fundamental difficulties.",
    "The capital of France is Paris. The capital of Italy is Rome. The "
    "capital of Spain is Madrid. The capital of Portugal is Lisbon. The "
    "capital of Austria is Vienna. The capital of Poland is Warsaw. The "
    "capital of Greece is Athens. The capital of Norway is Oslo.",
    "She opened the box and found a brass key, a folded map, and a "
    "photograph of a house she had never seen. The key was cold. The map "
    "showed a coastline with no names on it, only a cross in faded ink "
    "and the word soon, written twice, in two different hands.",
    "To make the bread, first dissolve the yeast in warm water and let it "
    "stand for ten minutes. Add the flour and the salt, and knead until "
    "the dough is smooth and elastic. Cover the bowl with a damp cloth "
    "and let it rise in a warm place until doubled in size.",
    "In the beginning the engineers measured everything twice. Throughput "
    "was measured in tokens per second, latency in milliseconds, and the "
    "bandwidth of the uplink in bits. When the link was slow the queue "
    "grew, and when the queue grew the users complained, and when the "
    "users complained the engineers measured everything again.",
    "What is the answer, asked the student. The teacher looked out of the "
    "window for a long time. The answer, said the teacher at last, "
    "depends on the question, and the question depends on who is asking, "
    "and you have not yet told me who you are.",
    "The weather report promised rain by nightfall, heavy at times, with "
    "a wind from the southwest. Fishing boats stayed in the harbor. The "
    "lighthouse keeper wrote the pressure in his log, eight minutes past "
    "noon, and underlined it, because the glass was falling faster than "
    "he had ever seen it fall.",
    "Speculative decoding accelerates inference by letting a small draft "
    "model propose several tokens that a large target model verifies in "
    "parallel. When the draft distribution is close to the target "
    "distribution, most proposals are accepted, and the cost of the large "
    "model is amortized across the whole batch of drafted tokens.",
    "Once there was a fox who lived at the edge of the pine forest, and "
    "every morning the fox walked the same path to the river, and every "
    "morning the heron stood in the same shallow bend. Good morning, said "
    "the fox. The heron said nothing, because herons say nothing, and the "
    "fox respected that, as one professional respects another.",
    "The train left the station at seven in the morning and arrived at "
    "the border at noon. Papers, said the guard. The traveler handed over "
    "the papers. The guard read them slowly, twice, and then stamped them "
    "with a stamp shaped like an eagle, and the train went on into the "
    "mountains where the snow had not yet melted.",
    "Entropy measures the average uncertainty of a distribution. A sharply "
    "peaked distribution has low entropy and can be compressed into few "
    "bits, while a flat distribution has high entropy and resists "
    "compression. The same trade governs how many draft tokens survive "
    "verification: sharp distributions travel cheaply, flat ones do not.",
]

PROMPTS = [
    "The capital of France is",
    "She opened the box and found",
    "To make the bread, first",
    "The river ran slow and",
    "A distributed system is",
    "Good morning, said the",
    "The train left the station at",
    "Speculative decoding accelerates",
    "The weather report promised",
    "Entropy measures the average",
    "Once there was a fox who",
    "What is the answer, asked",
]


def corpus_text() -> str:
    return "\n\n".join(_PARAGRAPHS) + "\n"


def corpus_bytes() -> np.ndarray:
    """Whole corpus as uint8 token ids (byte-level tokenizer)."""
    return np.frombuffer(corpus_text().encode("utf-8"), dtype=np.uint8)


def corpus_sha() -> str:
    return hashlib.sha256(corpus_text().encode("utf-8")).hexdigest()[:16]


def encode(s: str) -> np.ndarray:
    return np.frombuffer(s.encode("utf-8"), dtype=np.uint8)


def decode(ids) -> str:
    return bytes(int(i) & 0xFF for i in ids).decode("utf-8", errors="replace")


def sample_batch(rng: np.random.Generator, batch: int, seq_len: int) -> np.ndarray:
    """Random contiguous windows of `seq_len+1` bytes (inputs + shifted targets)."""
    data = corpus_bytes()
    starts = rng.integers(0, len(data) - seq_len - 1, size=batch)
    return np.stack([data[s : s + seq_len + 1] for s in starts]).astype(np.int32)
