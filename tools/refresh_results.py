#!/usr/bin/env python3
"""Refresh the checked-in results/ files from a CI bench-results artifact.

The checked-in copies under results/ are analytic projections until they
are replaced by measured rows from CI's bench-smoke job, which uploads
everything it measures as the `bench-results` workflow artifact.  This
tool performs that refresh as a *pure value swap*: it verifies that the
artifact file carries exactly the key set (JSON) or header (CSV) of the
checked-in copy — the same invariant CI's key-drift gates enforce — and
only then overwrites the checked-in file.  Any schema difference aborts
the swap, because it means the refresh would need a code review, not a
value refresh.

Usage:
    python3 tools/refresh_results.py <artifact-dir> [--dry-run]

where <artifact-dir> is the unpacked bench-results artifact (the
directory holding pipelining.csv, BENCH_pipelining.json, ...).
"""

import json
import shutil
import sys
from pathlib import Path

# (artifact name, checked-in name, kind, array keys to key-check)
TARGETS = [
    ("pipelining.csv", "pipelining.csv", "csv", None),
    ("BENCH_pipelining.json", "BENCH_pipelining.json", "json",
     ["points", "tree", "fleet"]),
    ("serving_soak.csv", "serving_soak.csv", "csv", None),
    ("BENCH_serving.json", "BENCH_serving.json", "json", ["points"]),
    ("BENCH_hotpath.json", "BENCH_hotpath.json", "json", ["stages"]),
]


def entry_keys(arr):
    keys = set()
    for e in arr:
        keys |= set(e.keys())
    return keys


def check_json(artifact: Path, checked: Path, arrays):
    with open(artifact) as f:
        measured = json.load(f)
    with open(checked) as f:
        current = json.load(f)
    if set(measured) != set(current):
        return f"top-level keys differ: {sorted(set(measured) ^ set(current))}"
    for arr in arrays or []:
        mk = entry_keys(measured[arr])
        ck = entry_keys(current[arr])
        if mk != ck:
            return f"'{arr}' entry keys differ: {sorted(mk ^ ck)}"
    # the gated-stage invariant must hold in the artifact too: never
    # check in a measured hotpath run that leaked allocations
    if "stages" in measured:
        leaks = [s["name"] for s in measured["stages"]
                 if s.get("gated") == 1 and s.get("allocs_per_op") != 0]
        if leaks:
            return f"gated stages allocated: {leaks}"
    return None


def check_csv(artifact: Path, checked: Path):
    with open(artifact) as f:
        measured_hdr = f.readline().strip()
    with open(checked) as f:
        current_hdr = f.readline().strip()
    if measured_hdr != current_hdr:
        return f"header differs: {measured_hdr!r} vs {current_hdr!r}"
    return None


def main():
    args = [a for a in sys.argv[1:] if a != "--dry-run"]
    dry_run = "--dry-run" in sys.argv[1:]
    if len(args) != 1:
        sys.exit(__doc__)
    artifact_dir = Path(args[0])
    results_dir = Path(__file__).resolve().parent.parent / "results"

    failures, swapped = [], 0
    for artifact_name, checked_name, kind, arrays in TARGETS:
        artifact = artifact_dir / artifact_name
        checked = results_dir / checked_name
        if not artifact.exists():
            print(f"skip: {artifact_name} not in artifact")
            continue
        if not checked.exists():
            print(f"skip: {checked_name} has no checked-in copy")
            continue
        err = (check_json(artifact, checked, arrays) if kind == "json"
               else check_csv(artifact, checked))
        if err:
            failures.append(f"{artifact_name}: {err}")
            continue
        if dry_run:
            print(f"would refresh: {checked_name}")
        else:
            shutil.copyfile(artifact, checked)
            print(f"refreshed: {checked_name}")
        swapped += 1

    for f in failures:
        print(f"SCHEMA MISMATCH — not swapped: {f}", file=sys.stderr)
    if failures:
        sys.exit(1)
    if swapped == 0:
        sys.exit("nothing refreshed: no recognized files in the artifact")


if __name__ == "__main__":
    main()
