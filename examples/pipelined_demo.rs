//! Pipelined speculative sessions quickstart: the same request runs the
//! strictly alternating v2 protocol (one draft in flight) and then the
//! protocol-v3 pipeline at depths 2 and 4 over a high-RTT link, where
//! the round trip — not compute — dominates.  Depth 1 is bit-identical
//! to the old protocol; deeper pipelines hide the RTT behind drafting
//! at the price of some discarded speculation on rejections.
//!
//!   cargo run --release --example pipelined_demo
//!
//! Same knobs as `sqs-sd run --pipeline-depth 4` and
//! `sqs-sd fleet --pipeline-depth 4`.

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::{SdSession, SessionConfig, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetSim, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;

fn main() -> anyhow::Result<()> {
    // a 100 ms RTT link: every alternating round pays it in full
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.050,
        jitter_s: 0.0,
    };

    println!("== one session, 100ms RTT, window 4 ==");
    println!(
        "{:<7} {:>10} {:>9} {:>9} {:>10} {:>10}",
        "depth", "latency_s", "speedup", "batches", "discarded", "bits/tok"
    );
    let mut baseline = f64::NAN;
    for depth in [1usize, 2, 4] {
        let world = SyntheticWorld::new(64, 0.3, 2024);
        let draft = SyntheticDraft::new(world.clone(), 1_000_000);
        let target = SyntheticTarget::new(world.clone(), 4, 1_000_000);
        let cfg = SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.7,
            max_new_tokens: 96,
            max_batch_drafts: 4,
            seed: 7,
            timing: TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 },
            pipeline_depth: depth,
            ..Default::default()
        };
        let mut sess = SdSession::new(draft, target, SimulatedLink::new(link, 7), cfg);
        let res = sess.run(&[7, 21, 42])?;
        if depth == 1 {
            baseline = res.total_time_s;
        }
        println!(
            "{depth:<7} {:>10.3} {:>8.2}x {:>9} {:>10} {:>10.1}",
            res.total_time_s,
            baseline / res.total_time_s,
            res.batches.len(),
            res.discarded_batches,
            res.bits_per_token()
        );
    }
    println!("(depth 1 IS the v2 alternating protocol, bit for bit)");

    println!("\n== 6-device fleet, shared 100ms-RTT uplink ==");
    for depth in [1usize, 4] {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            temp: 0.7,
            max_new_tokens: 24,
            max_batch_drafts: 4,
            workload: Workload::Poisson { rate_hz: 2.0 },
            pipeline_depth: depth,
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(6, base);
        cfg.uplink_bps = 1e6;
        cfg.propagation_s = 0.050;
        cfg.mismatch = 0.3;
        cfg.requests_per_device = 4;
        cfg.seed = 7;
        let report = FleetSim::new(cfg).run()?;
        println!(
            "depth {depth}: latency mean {:.3}s p99 {:.3}s | uplink {:>5.1}% | {} discarded",
            report.latency.mean(),
            report.latency.p99(),
            100.0 * report.uplink_utilization,
            report.discarded_batches
        );
    }
    Ok(())
}
