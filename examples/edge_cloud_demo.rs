//! Edge–cloud demo: the paper's operating points side by side.
//!
//!     cargo run --release --example edge_cloud_demo
//!
//! Runs the same prompt through K-SQS, C-SQS, dense QS, and the cloud-only
//! AR baseline at two temperatures, printing the full latency
//! decomposition (SLM compute / uplink / LLM verify / downlink), the
//! resampling rate, and the bandwidth ledger — a miniature of Figure 2.

// PJRT-only example: a `synthetic-only` build compiles a stub instead.

#[cfg(feature = "pjrt")]
mod pjrt_only {
use sqs_sd::channel::LinkConfig;
use sqs_sd::coordinator::{PjrtStack, SessionConfig, SessionResult, TimingMode};
use sqs_sd::model::{decode, encode};
use sqs_sd::sqs::Policy;

fn row(name: &str, temp: f32, r: &SessionResult) {
    println!(
        "{name:<22} {temp:>4.1} {:>7} {:>8} {:>9.3} {:>8.1} {:>10.3} {:>8.2} {:>8.1} {:>9.0}",
        r.new_tokens(),
        r.batches.len(),
        r.total_time_s,
        1e3 * r.latency_per_token(),
        r.resampling_rate(),
        r.acceptance_rate(),
        r.mean_k(),
        r.bits_per_token(),
    );
}

pub fn main() -> anyhow::Result<()> {
    let stack = PjrtStack::load(1 << 30)?;
    let prompt = encode("Once there was a fox who");
    let link = LinkConfig::default(); // 1 Mbit/s up, 10 ms propagation

    println!("edge: SLM {} params | cloud: LLM {} params | uplink {} kbit/s",
             stack.slm.weights.total_params, stack.llm.weights.total_params,
             link.uplink_bps / 1e3);
    println!(
        "\n{:<22} {:>4} {:>7} {:>8} {:>9} {:>8} {:>10} {:>8} {:>8} {:>9}",
        "policy", "T", "tokens", "batches", "total_s", "ms/tok",
        "resample", "accept", "mean_K", "bits/tok"
    );

    for &temp in &[0.2f32, 0.9] {
        for policy in [
            Policy::KSqs { k: 8 },
            Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
            Policy::DenseQs,
        ] {
            let cfg = SessionConfig {
                policy,
                temp,
                max_new_tokens: 48,
                seed: 11,
                ..Default::default()
            };
            let mut sess = stack.session(link, cfg);
            let res = sess.run(&prompt)?;
            row(&policy.describe(), temp, &res);
        }
        // cloud-only AR baseline at the same temperature
        let mut ar = stack.ar_baseline(link, temp, 11, TimingMode::Measured);
        let res = ar.run(&prompt, 48)?;
        row("AR baseline (cloud)", temp, &res);
        println!();
    }

    // show one completion for flavour
    let cfg = SessionConfig {
        policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
        temp: 0.5,
        max_new_tokens: 64,
        seed: 4,
        ..Default::default()
    };
    let mut sess = stack.session(link, cfg);
    let res = sess.run(&prompt)?;
    println!("C-SQS completion @T=0.5: {:?}",
             decode(&res.tokens[res.prompt_len..]));
    Ok(())
}

}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    pjrt_only::main()
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("this example needs the pjrt feature (default build)");
}
