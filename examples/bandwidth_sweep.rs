//! Bandwidth sweep: how the budgeted draft length L^t and end-to-end
//! latency respond to the uplink rate — the paper's central motivation
//! (the edge-cloud link is the bottleneck; compression buys latency).
//!
//!     cargo run --release --example bandwidth_sweep
//!
//! Sweeps the uplink from 64 kbit/s to 8 Mbit/s for K-SQS, C-SQS, and the
//! dense-QS baseline, reporting tokens/batch, latency per token, and the
//! share of time spent on the wire.

// PJRT-only example: a `synthetic-only` build compiles a stub instead.

#[cfg(feature = "pjrt")]
mod pjrt_only {
use sqs_sd::channel::LinkConfig;
use sqs_sd::coordinator::{PjrtStack, SessionConfig};
use sqs_sd::model::encode;
use sqs_sd::sqs::Policy;

pub fn main() -> anyhow::Result<()> {
    let stack = PjrtStack::load(1 << 30)?;
    let prompt = encode("A distributed system is");

    println!(
        "{:<10} {:<22} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "uplink", "policy", "tok/batch", "ms/tok", "uplink_ms", "wire_share", "bits/tok"
    );

    for &kbps in &[64.0f64, 256.0, 1000.0, 8000.0] {
        let link = LinkConfig {
            uplink_bps: kbps * 1e3,
            downlink_bps: 10.0 * kbps * 1e3,
            propagation_s: 0.010,
            jitter_s: 0.0,
        };
        for policy in [
            Policy::KSqs { k: 8 },
            Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
            Policy::DenseQs,
        ] {
            let cfg = SessionConfig {
                policy,
                temp: 0.6,
                max_new_tokens: 48,
                seed: 3,
                ..Default::default()
            };
            let mut sess = stack.session(link, cfg);
            let res = sess.run(&prompt)?;
            let tokens_per_batch =
                res.new_tokens() as f64 / res.batches.len().max(1) as f64;
            let wire = (res.t_uplink_s + res.t_downlink_s) / res.total_time_s;
            println!(
                "{:<10} {:<22} {:>9.2} {:>10.1} {:>10.1} {:>9.0}% {:>10.0}",
                format!("{}k", kbps as u64),
                policy.describe(),
                tokens_per_batch,
                1e3 * res.latency_per_token(),
                1e3 * res.t_uplink_s / res.batches.len().max(1) as f64,
                100.0 * wire,
                res.bits_per_token(),
            );
        }
        println!();
    }
    Ok(())
}

}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    pjrt_only::main()
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("this example needs the pjrt feature (default build)");
}
