//! Link-adaptive control plane quickstart: one session rides out a
//! mid-run bandwidth drop (1 Mbit/s -> 250 kbit/s) under each control
//! mode, then an adaptive fleet contends for a congested shared uplink.
//!
//!   cargo run --release --example adaptive_demo
//!
//! Same knobs as `sqs-sd run --adaptive {off,aimd,window}` and
//! `sqs-sd fleet --adaptive aimd --uplink-budget-bits 600`.

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::control::AdaptiveMode;
use sqs_sd::coordinator::{SdSession, SessionConfig, TimingMode};
use sqs_sd::fleet::{DeviceProfile, FleetConfig, FleetSim, Workload};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;

const TARGET_BITS: usize = 600;

fn main() -> anyhow::Result<()> {
    println!("== one session, uplink drops to 250 kbit/s at round 10 ==");
    println!("{:<22} {:>10} {:>12} {:>12}", "mode", "latency_s", "bits/round", "bits/tok");
    for mode in [
        AdaptiveMode::Off,
        AdaptiveMode::Aimd { target_bits: TARGET_BITS },
        AdaptiveMode::Window { grow: 0.8, shrink: 0.5 },
    ] {
        let world = SyntheticWorld::new(64, 0.6, 2024);
        let draft = SyntheticDraft::new(world.clone(), 1_000_000);
        let target = SyntheticTarget::new(world.clone(), 15, 1_000_000);
        let link = SimulatedLink::new(LinkConfig::default(), 7)
            .with_uplink_schedule(vec![(10, 2.5e5)]);
        let cfg = SessionConfig {
            policy: Policy::KSqs { k: 8 },
            temp: 0.9,
            max_new_tokens: 128,
            seed: 7,
            timing: TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 },
            adaptive: mode,
            ..Default::default()
        };
        let mut sess = SdSession::new(draft, target, link, cfg);
        let res = sess.run(&[7, 21, 42])?;
        println!(
            "{:<22} {:>10.3} {:>12.0} {:>12.1}",
            sess.control.describe(),
            res.total_time_s,
            res.mean_bits_per_round(),
            res.bits_per_token()
        );
    }
    println!("(aimd holds bits/round near the {TARGET_BITS}b budget; static ignores the drop)");

    println!("\n== 8-device adaptive fleet, 250 kbit/s shared uplink ==");
    for mode in [AdaptiveMode::Off, AdaptiveMode::Aimd { target_bits: TARGET_BITS }] {
        let base = DeviceProfile {
            policy: Policy::KSqs { k: 8 },
            max_new_tokens: 24,
            workload: Workload::Poisson { rate_hz: 2.0 },
            adaptive: mode,
            ..Default::default()
        };
        let mut cfg = FleetConfig::uniform(8, base);
        cfg.uplink_bps = 2.5e5;
        cfg.requests_per_device = 4;
        cfg.seed = 7;
        let report = FleetSim::new(cfg).run()?;
        println!(
            "{:<8} latency mean {:.3}s p99 {:.3}s | uplink {:>5.1}% | {:.0} bits/round",
            mode.name(),
            report.latency.mean(),
            report.latency.p99(),
            100.0 * report.uplink_utilization,
            report.mean_bits_per_round()
        );
    }
    Ok(())
}
