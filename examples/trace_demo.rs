//! Flight-recorder quickstart: run one deliberately turbulent session —
//! high draft/target mismatch, a depth-3 pipeline, and protocol-v4 token
//! trees — with a `JsonlTracer` installed, then read the recording back
//! out: print the rollback / survivor timeline, export the full trace
//! as JSONL plus Chrome `trace_event` JSON you can drop into Perfetto
//! (<https://ui.perfetto.dev>) to see drafts, frames in the air, and
//! verify windows on one virtual-time canvas — then feed the JSONL to
//! the offline analyzer (`sqs-sd analyze`) for the critical-path and
//! rejection-attribution breakdown.
//!
//!   cargo run --release --example trace_demo
//!
//! The same recording is available from the CLI via
//! `sqs-sd fleet --trace-out trace.jsonl` (and `run --trace-out` on a
//! PJRT build); traces are a pure function of (config, seed).

use sqs_sd::channel::{LinkConfig, SimulatedLink};
use sqs_sd::coordinator::{SdSession, SessionConfig, TimingMode};
use sqs_sd::model::synthetic::{SyntheticDraft, SyntheticTarget, SyntheticWorld};
use sqs_sd::sqs::Policy;
use sqs_sd::trace::{JsonlTracer, TraceData, TraceSink};

fn main() -> anyhow::Result<()> {
    let link = LinkConfig {
        uplink_bps: 1e6,
        downlink_bps: 1e7,
        propagation_s: 0.030,
        jitter_s: 0.0,
    };
    // high mismatch: rejections are common, so the pipeline rolls back
    // epochs and the trees rarely survive along their trunk
    let world = SyntheticWorld::new(64, 0.8, 2024);
    let draft = SyntheticDraft::new(world.clone(), 1_000_000);
    let target = SyntheticTarget::new(world.clone(), 6, 1_000_000);
    let cfg = SessionConfig {
        policy: Policy::KSqs { k: 8 },
        temp: 0.9,
        max_new_tokens: 64,
        max_batch_drafts: 6,
        seed: 11,
        timing: TimingMode::Modeled { slm_step_s: 1.2e-3, llm_call_s: 4.0e-3 },
        pipeline_depth: 3,
        tree_branching: 2,
        ..Default::default()
    };
    let mut sess = SdSession::new(draft, target, SimulatedLink::new(link, 11), cfg);
    let (sink, tracer) = TraceSink::shared(JsonlTracer::new());
    sess.set_tracer(sink);
    let res = sess.run(&[7, 21, 42])?;

    let tr = tracer.lock().unwrap();
    let mut events = tr.events().to_vec();
    events.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.seq.cmp(&b.seq)));

    println!("== rollback / survivor timeline ==");
    for ev in &events {
        match &ev.data {
            TraceData::EpochRollback { epoch } => {
                println!("{:>9.4}s  rollback -> epoch {epoch}", ev.t);
            }
            TraceData::TreeSurvivor { node, depth, resampled } => {
                println!(
                    "{:>9.4}s  tree survivor: node {node} at depth {depth}{}",
                    ev.t,
                    if *resampled { " (+resample)" } else { "" }
                );
            }
            TraceData::FeedbackApplied { batch_seq, discarded: true, .. } => {
                println!("{:>9.4}s  batch {batch_seq} discarded (stale epoch)", ev.t);
            }
            _ => {}
        }
    }

    let count = |k: &str| events.iter().filter(|e| e.data.kind() == k).count();
    println!(
        "\n{} events | {} drafts | {} rollbacks | {} survivors",
        events.len(),
        count("draft_sent"),
        count("epoch_rollback"),
        count("tree_survivor"),
    );
    println!(
        "session: {} tokens in {:.3}s virtual | {} batches, {} discarded",
        res.new_tokens(),
        res.total_time_s,
        res.batches.len(),
        res.discarded_batches
    );

    let jsonl = tr.jsonl();
    std::fs::write("trace_demo.jsonl", &jsonl)?;
    std::fs::write("trace_demo.trace.json", tr.chrome_json())?;
    println!("\nwrote trace_demo.jsonl + trace_demo.trace.json (open at https://ui.perfetto.dev)");

    // close the loop: the offline analyzer over the recording we just
    // made — same breakdown `sqs-sd analyze --trace trace_demo.jsonl`
    // prints, bit-identical on every rerun of this example
    let report = sqs_sd::analysis::analyze_jsonl(&jsonl).map_err(anyhow::Error::msg)?;
    println!("\n== offline analyzer ==\n{}", report.render());
    std::fs::write("trace_demo.report.json", report.to_json().to_string_pretty())?;
    std::fs::write("trace_demo.report.csv", report.to_csv())?;
    println!("wrote trace_demo.report.json + trace_demo.report.csv");
    Ok(())
}
