//! Fleet simulator quickstart: 12 heterogeneous devices with a mix of
//! policies contend for one 1 Mbit/s uplink and a 2-slot batching cloud
//! verifier.  Runs in virtual time — finishes in milliseconds of wall
//! clock and prints the fleet-wide latency/utilization report.
//!
//!   cargo run --release --example fleet_demo

use sqs_sd::fleet::{
    heterogeneous_profiles, mixed_policy_profiles, DeviceProfile, FleetConfig, FleetSim,
    VerifierConfig, Workload,
};

fn main() -> anyhow::Result<()> {
    let base = DeviceProfile {
        max_new_tokens: 32,
        workload: Workload::Poisson { rate_hz: 2.0 },
        ..Default::default()
    };
    // heterogeneous draft speeds/downlinks, then a ksqs/csqs/dense mix
    let profiles = mixed_policy_profiles(12, base)
        .into_iter()
        .zip(heterogeneous_profiles(12, base, 77))
        .map(|(mixed, het)| DeviceProfile {
            policy: mixed.policy,
            draft_token_s: het.draft_token_s,
            downlink_bps: het.downlink_bps,
            workload: het.workload,
            ..base
        })
        .collect();

    let cfg = FleetConfig {
        profiles,
        uplink_bps: 1e6,
        uplink_schedule: Vec::new(),
        propagation_s: 0.010,
        jitter_s: 0.002,
        requests_per_device: 5,
        verifier: VerifierConfig { concurrency: 2, batch_max: 6, ..Default::default() },
        vocab: 64,
        mismatch: 0.6,
        seed: 7,
        record_trace: false,
    };
    let report = FleetSim::new(cfg).run()?;
    print!("{}", report.render());
    println!("--- per-device ---");
    for d in &report.per_device {
        println!(
            "dev{:02} {:<8} {} reqs | mean {:.3}s p99 {:.3}s | {} uplink bits",
            d.id, d.policy, d.completed, d.mean_latency_s, d.p99_latency_s, d.uplink_bits
        );
    }
    Ok(())
}
