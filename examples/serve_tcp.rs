//! TCP serving demo: start the server, drive it with a small client
//! workload over real sockets, print the responses.
//!
//!     cargo run --release --example serve_tcp
//!
//! The server owns the PJRT stack on its inference thread; connections are
//! handled by acceptor threads feeding a FIFO job queue (see
//! rust/src/server/mod.rs for the protocol).

// PJRT-only example: a `synthetic-only` build compiles a stub instead.

#[cfg(feature = "pjrt")]
mod pjrt_only {
use sqs_sd::server::{serve, Client, ServerConfig};
use sqs_sd::util::json::Json;

pub fn main() -> anyhow::Result<()> {
    let addr = "127.0.0.1:7171";
    let n_requests = 6;

    // server thread (exits after n_requests)
    let server_addr = addr.to_string();
    let server = std::thread::spawn(move || {
        serve(ServerConfig {
            addr: server_addr,
            max_requests: Some(n_requests),
            ..Default::default()
        })
        .expect("server runs");
    });

    // wait for the listener, then connect
    let client = loop {
        match Client::connect(addr) {
            Ok(c) => break c,
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    };

    let prompts = [
        ("The capital of France is", "ksqs"),
        ("Once there was a fox who", "csqs"),
        ("To make the bread, first", "csqs"),
        ("A distributed system is", "ksqs"),
        ("The train left the station at", "dense"),
        ("She opened the box and found", "csqs"),
    ];
    for (prompt, policy) in prompts.iter().take(n_requests) {
        let req = Json::obj(vec![
            ("prompt", Json::Str(prompt.to_string())),
            ("policy", Json::Str(policy.to_string())),
            ("max_tokens", Json::Num(32.0)),
            ("temp", Json::Num(0.5)),
        ]);
        let resp = client.request(&req)?;
        if let Some(err) = resp.get("error") {
            println!("{policy:>5} | {prompt:<32} | ERROR {err:?}");
            continue;
        }
        println!(
            "{policy:>5} | {prompt:<32} -> {:?}  [{} tok, {:.0} bits/tok, rr {:.2}, {:.0} ms sim]",
            resp.get("text").and_then(|t| t.as_str()).unwrap_or(""),
            resp.get("tokens").and_then(|t| t.as_f64()).unwrap_or(0.0),
            resp.get("bits_per_token").and_then(|t| t.as_f64()).unwrap_or(0.0),
            resp.get("resampling_rate").and_then(|t| t.as_f64()).unwrap_or(0.0),
            1e3 * resp.get("latency_s").and_then(|t| t.as_f64()).unwrap_or(0.0),
        );
    }

    server.join().expect("server thread");
    Ok(())
}

}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    pjrt_only::main()
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("this example needs the pjrt feature (default build)");
}
