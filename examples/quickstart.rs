//! Quickstart: load the AOT artifacts and run one C-SQS speculative-
//! decoding session end to end.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest complete use of the public API: a `PjrtStack`
//! (PJRT engine + compiled modules + device weights), a `SessionConfig`
//! choosing the paper's C-SQS policy at its published operating point
//! (B = 5000 bits, ell = 100, eta = 0.001, alpha = 0.0005), and one
//! session over a simulated 1 Mbit/s uplink.

// PJRT-only example: a `synthetic-only` build compiles a stub instead.

#[cfg(feature = "pjrt")]
mod pjrt_only {
use sqs_sd::channel::LinkConfig;
use sqs_sd::coordinator::{PjrtStack, SessionConfig};
use sqs_sd::model::{decode, encode};
use sqs_sd::sqs::Policy;

pub fn main() -> anyhow::Result<()> {
    // PJRT engine + compiled HLO modules + device-resident weights
    let stack = PjrtStack::load(1 << 30)?;
    println!("platform: {} | slm {} params | llm {} params",
             stack.engine.platform(),
             stack.slm.weights.total_params,
             stack.llm.weights.total_params);

    let cfg = SessionConfig {
        policy: Policy::CSqs { beta0: 0.01, alpha: 0.0005, eta: 0.001 },
        temp: 0.7,
        ell: 100,
        budget_bits: 5000,
        max_new_tokens: 64,
        seed: 7,
        ..Default::default()
    };

    let prompt = "The capital of France is";
    let mut session = stack.session(LinkConfig::default(), cfg);
    let res = session.run(&encode(prompt))?;

    println!("\nprompt     : {prompt}");
    println!("completion : {:?}", decode(&res.tokens[res.prompt_len..]));
    println!("\n{} new tokens in {} speculative batches", res.new_tokens(),
             res.batches.len());
    println!("latency    : {:.3}s simulated  ({:.1} ms/token)",
             res.total_time_s, 1e3 * res.latency_per_token());
    println!("  slm compute {:.3}s | uplink {:.3}s | llm verify {:.3}s | downlink {:.3}s",
             res.t_slm_s, res.t_uplink_s, res.t_llm_s, res.t_downlink_s);
    println!("uplink     : {} bits total, {:.0} bits/token (raw f32 would be {})",
             res.uplink_bits, res.bits_per_token(),
             sqs_sd::sqs::bits::raw_f32_bits(256));
    println!("resampling : {:.3} per batch | acceptance {:.2} | mean support K {:.1}",
             res.resampling_rate(), res.acceptance_rate(), res.mean_k());
    if let (Some(emp), Some(bound)) = (res.conformal_empirical_alpha, res.conformal_bound) {
        println!("conformal  : empirical alpha {emp:.5} <= Theorem-2 bound {bound:.5}");
    }
    Ok(())
}

}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    pjrt_only::main()
}

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!("this example needs the pjrt feature (default build)");
}
